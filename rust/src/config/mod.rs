//! Configuration system: workload topologies, synthesis-time accelerator
//! builds, and the runtime-programmable register image.
//!
//! The paper's key flexibility split (Section IV.C / VI):
//! * **Synthesis-time** (fixed once "bitstream" is built): tile size `TS`,
//!   data width, target device, and the *maxima* for (h, d_model, SL).
//! * **Runtime-programmable** (per request, via MicroBlaze → AXI-lite):
//!   heads `h`, embedding dimension `d_model`, sequence length `SL`,
//!   each up to its synthesized maximum.

mod topology;

pub use topology::Topology;

use crate::fpga::device::Device;
use crate::jsonlite::Json;
use std::fmt;

/// Synthesis-time accelerator build (what one "bitstream" fixes).
#[derive(Clone, Debug, PartialEq)]
pub struct AcceleratorConfig {
    /// Tile size `TS`: column width of the weight tiles (Fig. 4).
    pub tile_size: usize,
    /// Datapath width in bits (paper: 8-bit fixed point).
    pub data_bits: u32,
    /// Fabric clock in Hz (paper reports results around 400 MHz).
    pub clock_hz: f64,
    /// Target device (resource inventory + feasibility).
    pub device: Device,
    /// Synthesized maxima for the runtime-programmable parameters.
    pub max_topology: Topology,
}

impl AcceleratorConfig {
    /// The paper's U55C build: TS=64, 8-bit, maxima (SL=128, d=768, h=8).
    pub fn u55c_ts64() -> Self {
        AcceleratorConfig {
            tile_size: 64,
            data_bits: 8,
            clock_hz: 400e6,
            device: Device::alveo_u55c(),
            max_topology: Topology::new(128, 768, 8, 64),
        }
    }

    /// The paper's U200 build: h max 6 (LUT-bound, Section VI).
    pub fn u200_ts64() -> Self {
        AcceleratorConfig {
            tile_size: 64,
            data_bits: 8,
            clock_hz: 400e6,
            device: Device::alveo_u200(),
            max_topology: Topology::new(128, 768, 6, 64),
        }
    }

    /// A long-sequence U55C variant: the same TS-64 datapath synthesized
    /// with the fused tile-streaming attention unit (DESIGN.md §12), so
    /// the per-head score buffer is SL×TS rather than SL² and the SL
    /// ceiling rises to 1024.  This is a *hypothetical* build beyond the
    /// paper's Table I (which caps at SL=128); the timing model keeps
    /// the same loop algebra, just with longer loops.
    pub fn u55c_ts64_sl1024() -> Self {
        let mut c = Self::u55c_ts64();
        c.max_topology = Topology::new(1024, 768, 8, 64);
        c
    }

    /// U55C rebuilt with a different tile size (tests 9–10).
    pub fn u55c_with_tile_size(ts: usize) -> Self {
        let mut c = Self::u55c_ts64();
        c.tile_size = ts;
        c.max_topology.tile_size = ts;
        c
    }

    /// Can `topo` run on this build without re-synthesis?
    /// (Runtime programmability contract, Section IV.C.)
    pub fn admits(&self, topo: &Topology) -> Result<(), ConfigError> {
        topo.validate()?;
        let m = &self.max_topology;
        if topo.tile_size != self.tile_size {
            return Err(ConfigError::NeedsResynthesis {
                param: "tile_size",
                requested: topo.tile_size,
                built: self.tile_size,
            });
        }
        for (param, req, max) in [
            ("seq_len", topo.seq_len, m.seq_len),
            ("d_model", topo.d_model, m.d_model),
            ("heads", topo.heads, m.heads),
        ] {
            if req > max {
                return Err(ConfigError::ExceedsSynthesizedMax { param, requested: req, max });
            }
        }
        Ok(())
    }

    /// Cycles → milliseconds at this build's clock.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.clock_hz * 1e3
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("tile_size", Json::from(self.tile_size as f64)),
            ("data_bits", Json::from(self.data_bits as f64)),
            ("clock_hz", Json::from(self.clock_hz)),
            ("device", Json::from(self.device.name.as_str())),
            ("max_topology", self.max_topology.to_json()),
        ])
    }
}

/// Errors surfaced by config validation and admission control.
#[derive(Clone, Debug, PartialEq)]
pub enum ConfigError {
    /// d_model not divisible by heads / tile_size, zero dims, ...
    InvalidTopology(String),
    /// Requested parameter exceeds the synthesized maximum: the hardware
    /// would need a new bitstream (what FAMOUS exists to avoid).
    ExceedsSynthesizedMax { param: &'static str, requested: usize, max: usize },
    /// Parameter is synthesis-time only (tile size, data width).
    NeedsResynthesis { param: &'static str, requested: usize, built: usize },
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConfigError::InvalidTopology(m) => write!(f, "invalid topology: {m}"),
            ConfigError::ExceedsSynthesizedMax { param, requested, max } => write!(
                f,
                "{param}={requested} exceeds synthesized maximum {max} (needs re-synthesis)"
            ),
            ConfigError::NeedsResynthesis { param, requested, built } => write!(
                f,
                "{param}={requested} differs from synthesized {built}: synthesis-time parameter"
            ),
        }
    }
}

impl std::error::Error for ConfigError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u55c_admits_all_table1_runtime_tests() {
        let c = AcceleratorConfig::u55c_ts64();
        for (sl, dm, h) in [
            (64, 768, 8),
            (64, 768, 4),
            (64, 768, 2),
            (64, 512, 8),
            (64, 256, 8),
            (128, 768, 8),
            (32, 768, 8),
            (16, 768, 8),
        ] {
            let t = Topology::new(sl, dm, h, 64);
            assert!(c.admits(&t).is_ok(), "{t:?}");
        }
    }

    #[test]
    fn tile_size_change_needs_resynthesis() {
        let c = AcceleratorConfig::u55c_ts64();
        let t = Topology::new(64, 768, 8, 32);
        assert!(matches!(
            c.admits(&t),
            Err(ConfigError::NeedsResynthesis { param: "tile_size", .. })
        ));
    }

    #[test]
    fn exceeding_max_heads_rejected() {
        let c = AcceleratorConfig::u200_ts64();
        let t = Topology::new(64, 768, 8, 64); // h=8 > built max 6
        assert!(matches!(
            c.admits(&t),
            Err(ConfigError::ExceedsSynthesizedMax { param: "heads", .. })
        ));
    }

    #[test]
    fn cycles_to_ms_at_400mhz() {
        let c = AcceleratorConfig::u55c_ts64();
        assert!((c.cycles_to_ms(400_000) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn resynthesized_build_admits_new_ts() {
        let c = AcceleratorConfig::u55c_with_tile_size(32);
        assert!(c.admits(&Topology::new(64, 768, 8, 32)).is_ok());
    }
}
