//! Execution substrate: a worker thread pool + bounded MPSC channels
//! (tokio is unavailable offline; the coordinator's event loop runs on
//! these primitives instead).
//!
//! The pool is deliberately simple: a shared injector queue guarded by a
//! mutex + condvar.  The serving hot path batches work coarsely (one job
//! per request, a handful of head-lane jobs inside each — see
//! [`PoolHandle::scoped_mut`] and DESIGN.md §10), so queue contention is
//! negligible — see EXPERIMENTS.md §Perf for measurements.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("famous-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (at least 2 workers).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Cheap cloneable submission handle (no join rights): lets jobs
    /// running *on* the pool fan further work out to it — the head-level
    /// lanes of the two-level MHA execute path.
    pub fn handle(&self) -> PoolHandle {
        PoolHandle { shared: Arc::clone(&self.shared) }
    }

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        spawn_on(&self.shared, job);
    }

    /// Run `f(i, &mut items[i])` for every item on the pool, returning
    /// only when all invocations have finished.  See
    /// [`PoolHandle::scoped_mut`].
    pub fn scoped_mut<T, F>(&self, items: &mut [T], f: &F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        scoped_mut_on(&self.shared, items, f);
    }

    /// Block until every spawned job has finished.
    pub fn wait_idle(&self) {
        let q = self.shared.queue.lock().unwrap();
        let _guard = self
            .shared
            .done
            .wait_while(q, |q| {
                !q.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0
            })
            .unwrap();
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.spawn(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("results still shared after wait_idle"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job completed"))
            .collect()
    }
}

fn spawn_on(shared: &Shared, job: impl FnOnce() + Send + 'static) {
    shared.in_flight.fetch_add(1, Ordering::SeqCst);
    let mut q = shared.queue.lock().unwrap();
    q.push_back(Box::new(job));
    drop(q);
    shared.available.notify_one();
}

/// Execute one dequeued job with the in-flight accounting both the
/// workers and the help-while-waiting loop need.
fn run_job(s: &Shared, job: Job) {
    // A panicking job must not wedge wait_idle: decrement via guard.
    struct Guard<'a>(&'a Shared);
    impl Drop for Guard<'_> {
        fn drop(&mut self) {
            // Decrement under the queue lock: wait_idle evaluates its
            // predicate while holding it, so an unlocked decrement +
            // notify could land in the window between a waiter's
            // predicate check and its park — a lost wakeup that would
            // hang parallel_map (and with it the serving batch path).
            let _q = self.0.queue.lock().unwrap();
            self.0.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.0.done.notify_all();
        }
    }
    let _g = Guard(s);
    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
}

fn worker_loop(s: Arc<Shared>) {
    loop {
        let job = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = s.available.wait(q).unwrap();
            }
        };
        run_job(&s, job);
    }
}

/// Submission-only handle to a [`ThreadPool`] (cloneable, `Send + Sync`).
#[derive(Clone)]
pub struct PoolHandle {
    shared: Arc<Shared>,
}

impl PoolHandle {
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        spawn_on(&self.shared, job);
    }

    /// Run `f(i, &mut items[i])` for every item on the pool, blocking
    /// until all invocations have finished.  The calling thread takes
    /// item 0 itself and *helps* — it executes queued pool jobs while its
    /// own are outstanding — so a scoped call issued from inside a pool
    /// job (nested parallelism: batch-level jobs fanning out head-level
    /// lanes) always makes progress instead of deadlocking on a pool
    /// whose every worker is itself waiting.
    ///
    /// A panic in any invocation is re-raised here — with its original
    /// payload — after all items have completed or unwound.
    pub fn scoped_mut<T, F>(&self, items: &mut [T], f: &F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        scoped_mut_on(&self.shared, items, f);
    }
}

/// Completion latch for one `scoped_mut` call.
struct ScopeLatch {
    remaining: Mutex<usize>,
    done: Condvar,
    /// First spawned job's panic payload, re-raised by the caller.
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl ScopeLatch {
    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *self.remaining.lock().unwrap() == 0
    }
}

// Send-erased pointers for the scoped jobs.  Soundness rests on
// `scoped_mut_on` not returning until every job has run: the pointees
// (the items slice and the closure, both borrowed by the caller)
// outlive every dereference.
struct ErasedConst(*const ());
unsafe impl Send for ErasedConst {}
struct ErasedMut(*mut ());
unsafe impl Send for ErasedMut {}

fn scoped_mut_on<T, F>(shared: &Arc<Shared>, items: &mut [T], f: &F)
where
    T: Send,
    F: Fn(usize, &mut T) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    // Monomorphic shim behind type-erased pointers: the spawned closures
    // then mention neither `T` nor `F`, so they satisfy `spawn`'s
    // `'static` bound even though both borrow from the caller.
    unsafe fn shim<T, F: Fn(usize, &mut T)>(f: *const (), i: usize, item: *mut ()) {
        let f = unsafe { &*(f as *const F) };
        f(i, unsafe { &mut *(item as *mut T) });
    }
    let call: unsafe fn(*const (), usize, *mut ()) = shim::<T, F>;
    let base = items.as_mut_ptr();
    let latch = Arc::new(ScopeLatch {
        remaining: Mutex::new(n - 1),
        done: Condvar::new(),
        panic: Mutex::new(None),
    });
    for i in 1..n {
        let item = ErasedMut(unsafe { base.add(i) } as *mut ());
        let fdata = ErasedConst(f as *const F as *const ());
        let latch = Arc::clone(&latch);
        spawn_on(shared, move || {
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
                call(fdata.0, i, item.0)
            }));
            if let Err(p) = r {
                let mut slot = latch.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(p);
                }
            }
            latch.count_down();
        });
    }
    // The caller's share: item 0, inline (no queue round-trip).  Via
    // `base`, not a fresh `&mut items[0]`, so the raw pointers handed to
    // the jobs stay valid under strict aliasing.
    let first = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        f(0, unsafe { &mut *base })
    }));
    // Help while waiting: run queued jobs instead of parking a worker
    // that could be working.  Pop from the *back* — our lane jobs were
    // enqueued last, so LIFO stealing drains them first rather than
    // pulling an older foreign batch job onto this stack (which would
    // nest a whole request and stall our own lanes behind it); workers
    // proper keep FIFO order via pop_front.
    loop {
        if latch.is_done() {
            break;
        }
        let job = shared.queue.lock().unwrap().pop_back();
        match job {
            Some(job) => run_job(shared, job),
            None => {
                let g = latch.remaining.lock().unwrap();
                if *g == 0 {
                    break;
                }
                // Timed wait: our jobs are all enqueued before this loop,
                // so a count_down wakeup suffices; the timeout only guards
                // against a theoretical missed notify.
                let _ = latch
                    .done
                    .wait_timeout(g, std::time::Duration::from_micros(500))
                    .unwrap();
            }
        }
    }
    if let Err(p) = first {
        std::panic::resume_unwind(p);
    }
    let payload = latch.panic.lock().unwrap().take();
    if let Some(p) = payload {
        std::panic::resume_unwind(p);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded MPSC channel with blocking send (backpressure) — the
/// coordinator's ingress queue.
pub struct BoundedSender<T> {
    inner: Arc<ChannelInner<T>>,
}

pub struct BoundedReceiver<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    queue: Mutex<VecDeque<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
    closed: AtomicBool,
}

/// Create a bounded channel of capacity `cap`.
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    assert!(cap > 0);
    let inner = Arc::new(ChannelInner {
        queue: Mutex::new(VecDeque::new()),
        cap,
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        closed: AtomicBool::new(false),
    });
    (BoundedSender { inner: Arc::clone(&inner) }, BoundedReceiver { inner })
}

/// Error returned when the peer has hung up.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

impl<T> BoundedSender<T> {
    /// Blocking send; returns Err(Closed) if the receiver dropped.
    pub fn send(&self, v: T) -> Result<(), Closed> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if self.inner.closed.load(Ordering::SeqCst) {
                return Err(Closed);
            }
            if q.len() < self.inner.cap {
                q.push_back(v);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            q = self.inner.not_full.wait(q).unwrap();
        }
    }

    /// Non-blocking send; Err(v) gives the value back if full/closed.
    pub fn try_send(&self, v: T) -> Result<(), T> {
        if self.inner.closed.load(Ordering::SeqCst) {
            return Err(v);
        }
        let mut q = self.inner.queue.lock().unwrap();
        if q.len() < self.inner.cap {
            q.push_back(v);
            self.inner.not_empty.notify_one();
            Ok(())
        } else {
            Err(v)
        }
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Has the receiving side hung up?  True once the receiver dropped
    /// (or called `close`) — every subsequent send fails.
    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::SeqCst)
    }
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        BoundedSender { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.not_full.notify_all();
    }
}

impl<T> BoundedReceiver<T> {
    /// Blocking receive; None once all senders dropped and queue drained.
    pub fn recv(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if Arc::strong_count(&self.inner) <= 1 || self.inner.closed.load(Ordering::SeqCst) {
                return None;
            }
            let (guard, timeout) = self
                .inner
                .not_empty
                .wait_timeout(q, std::time::Duration::from_millis(20))
                .unwrap();
            q = guard;
            let _ = timeout; // periodic wake to observe sender drops
        }
    }

    /// Drain up to `max` immediately-available items (batch ingress).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut q = self.inner.queue.lock().unwrap();
        let take = max.min(q.len());
        let out: Vec<T> = q.drain(..take).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.not_full.notify_all();
        self.inner.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| panic!("boom"));
        pool.wait_idle();
        let ok = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&ok);
        pool.spawn(move || {
            c.store(7, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn scoped_mut_runs_every_item() {
        let pool = ThreadPool::new(3);
        let mut items: Vec<u64> = (0..17).collect();
        pool.scoped_mut(&mut items, &|i, v: &mut u64| {
            *v += i as u64 * 100;
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i as u64 * 101);
        }
    }

    #[test]
    fn scoped_mut_on_single_worker_pool() {
        let pool = ThreadPool::new(1);
        let mut items = vec![0u64; 8];
        pool.handle().scoped_mut(&mut items, &|i, v: &mut u64| *v = i as u64 + 1);
        assert_eq!(items, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn scoped_mut_empty_and_singleton() {
        let pool = ThreadPool::new(2);
        let mut empty: Vec<u32> = Vec::new();
        pool.scoped_mut(&mut empty, &|_, _: &mut u32| unreachable!());
        let mut one = vec![7u32];
        pool.scoped_mut(&mut one, &|i, v: &mut u32| *v += i as u32 + 1);
        assert_eq!(one, vec![8]);
    }

    #[test]
    fn nested_scoped_inside_pool_jobs_makes_progress() {
        // Batch-level parallel_map whose jobs each run a head-level scope
        // on the same (undersized) pool: the help-while-waiting loop must
        // prevent the all-workers-waiting deadlock.
        let pool = ThreadPool::new(2);
        let handle = pool.handle();
        let out = pool.parallel_map((0..6).collect(), move |x: i32| {
            let mut items = vec![0i32; 4];
            handle.scoped_mut(&mut items, &|i, v: &mut i32| *v = x * 10 + i as i32);
            items.iter().sum::<i32>()
        });
        assert_eq!(out, (0..6).map(|x| x * 40 + 6).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "boom in lane 3")]
    fn scoped_mut_propagates_job_panic_payload() {
        let pool = ThreadPool::new(2);
        let mut items = vec![0u8; 4];
        pool.scoped_mut(&mut items, &|i, _v: &mut u8| {
            if i == 3 {
                panic!("boom in lane {i}")
            }
        });
    }

    #[test]
    fn channel_fifo() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn channel_backpressure_try_send() {
        let (tx, _rx) = bounded(2);
        assert!(tx.try_send(1).is_ok());
        assert!(tx.try_send(2).is_ok());
        assert_eq!(tx.try_send(3), Err(3)); // full
    }

    #[test]
    fn sender_observes_receiver_drop() {
        let (tx, rx) = bounded::<i32>(1);
        assert!(!tx.is_closed());
        drop(rx);
        assert!(tx.is_closed());
    }

    #[test]
    fn recv_none_after_senders_drop() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(rx);
        assert_eq!(tx.send(1), Err(Closed));
    }

    #[test]
    fn drain_up_to_batches() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let batch = rx.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(rx.drain_up_to(100), vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn blocking_send_unblocks_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let t = std::thread::spawn(move || tx.send(1).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(rx.recv(), Some(0));
        t.join().unwrap();
        assert_eq!(rx.recv(), Some(1));
    }
}
