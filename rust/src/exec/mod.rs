//! Execution substrate: a worker thread pool + bounded MPSC channels
//! (tokio is unavailable offline; the coordinator's event loop runs on
//! these primitives instead).
//!
//! The pool is deliberately simple: a shared injector queue guarded by a
//! mutex + condvar.  The coordinator's hot path batches work coarsely
//! (one job per request batch), so queue contention is negligible — see
//! EXPERIMENTS.md §Perf for measurements.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    done: Condvar,
}

/// Fixed-size worker pool.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0, "thread pool needs at least one worker");
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("famous-worker-{i}"))
                    .spawn(move || worker_loop(s))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Pool sized to the machine (at least 2 workers).
    pub fn default_size() -> Self {
        let n = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.max(2))
    }

    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(Box::new(job));
        drop(q);
        self.shared.available.notify_one();
    }

    /// Block until every spawned job has finished.
    pub fn wait_idle(&self) {
        let q = self.shared.queue.lock().unwrap();
        let _guard = self
            .shared
            .done
            .wait_while(q, |q| {
                !q.is_empty() || self.shared.in_flight.load(Ordering::SeqCst) > 0
            })
            .unwrap();
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn parallel_map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let results = Arc::clone(&results);
            self.spawn(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("results still shared after wait_idle"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("job completed"))
            .collect()
    }
}

fn worker_loop(s: Arc<Shared>) {
    loop {
        let job = {
            let mut q = s.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if s.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = s.available.wait(q).unwrap();
            }
        };
        // A panicking job must not wedge wait_idle: decrement via guard.
        struct Guard<'a>(&'a Shared);
        impl Drop for Guard<'_> {
            fn drop(&mut self) {
                // Decrement under the queue lock: wait_idle evaluates its
                // predicate while holding it, so an unlocked decrement +
                // notify could land in the window between a waiter's
                // predicate check and its park — a lost wakeup that would
                // hang parallel_map (and with it the serving batch path).
                let _q = self.0.queue.lock().unwrap();
                self.0.in_flight.fetch_sub(1, Ordering::SeqCst);
                self.0.done.notify_all();
            }
        }
        let _g = Guard(&s);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded MPSC channel with blocking send (backpressure) — the
/// coordinator's ingress queue.
pub struct BoundedSender<T> {
    inner: Arc<ChannelInner<T>>,
}

pub struct BoundedReceiver<T> {
    inner: Arc<ChannelInner<T>>,
}

struct ChannelInner<T> {
    queue: Mutex<VecDeque<T>>,
    cap: usize,
    not_full: Condvar,
    not_empty: Condvar,
    closed: AtomicBool,
}

/// Create a bounded channel of capacity `cap`.
pub fn bounded<T>(cap: usize) -> (BoundedSender<T>, BoundedReceiver<T>) {
    assert!(cap > 0);
    let inner = Arc::new(ChannelInner {
        queue: Mutex::new(VecDeque::new()),
        cap,
        not_full: Condvar::new(),
        not_empty: Condvar::new(),
        closed: AtomicBool::new(false),
    });
    (BoundedSender { inner: Arc::clone(&inner) }, BoundedReceiver { inner })
}

/// Error returned when the peer has hung up.
#[derive(Debug, PartialEq, Eq)]
pub struct Closed;

impl<T> BoundedSender<T> {
    /// Blocking send; returns Err(Closed) if the receiver dropped.
    pub fn send(&self, v: T) -> Result<(), Closed> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if self.inner.closed.load(Ordering::SeqCst) {
                return Err(Closed);
            }
            if q.len() < self.inner.cap {
                q.push_back(v);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            q = self.inner.not_full.wait(q).unwrap();
        }
    }

    /// Non-blocking send; Err(v) gives the value back if full/closed.
    pub fn try_send(&self, v: T) -> Result<(), T> {
        if self.inner.closed.load(Ordering::SeqCst) {
            return Err(v);
        }
        let mut q = self.inner.queue.lock().unwrap();
        if q.len() < self.inner.cap {
            q.push_back(v);
            self.inner.not_empty.notify_one();
            Ok(())
        } else {
            Err(v)
        }
    }

    pub fn len(&self) -> usize {
        self.inner.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for BoundedSender<T> {
    fn clone(&self) -> Self {
        BoundedSender { inner: Arc::clone(&self.inner) }
    }
}

impl<T> Drop for BoundedReceiver<T> {
    fn drop(&mut self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.not_full.notify_all();
    }
}

impl<T> BoundedReceiver<T> {
    /// Blocking receive; None once all senders dropped and queue drained.
    pub fn recv(&self) -> Option<T> {
        let mut q = self.inner.queue.lock().unwrap();
        loop {
            if let Some(v) = q.pop_front() {
                self.inner.not_full.notify_one();
                return Some(v);
            }
            if Arc::strong_count(&self.inner) <= 1 || self.inner.closed.load(Ordering::SeqCst) {
                return None;
            }
            let (guard, timeout) = self
                .inner
                .not_empty
                .wait_timeout(q, std::time::Duration::from_millis(20))
                .unwrap();
            q = guard;
            let _ = timeout; // periodic wake to observe sender drops
        }
    }

    /// Drain up to `max` immediately-available items (batch ingress).
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut q = self.inner.queue.lock().unwrap();
        let take = max.min(q.len());
        let out: Vec<T> = q.drain(..take).collect();
        if !out.is_empty() {
            self.inner.not_full.notify_all();
        }
        out
    }

    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.inner.not_full.notify_all();
        self.inner.not_empty.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.spawn(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.parallel_map((0..50).collect(), |x: i32| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn pool_survives_panicking_job() {
        let pool = ThreadPool::new(2);
        pool.spawn(|| panic!("boom"));
        pool.wait_idle();
        let ok = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&ok);
        pool.spawn(move || {
            c.store(7, Ordering::SeqCst);
        });
        pool.wait_idle();
        assert_eq!(ok.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn channel_fifo() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        for i in 0..5 {
            assert_eq!(rx.recv(), Some(i));
        }
    }

    #[test]
    fn channel_backpressure_try_send() {
        let (tx, _rx) = bounded(2);
        assert!(tx.try_send(1).is_ok());
        assert!(tx.try_send(2).is_ok());
        assert_eq!(tx.try_send(3), Err(3)); // full
    }

    #[test]
    fn recv_none_after_senders_drop() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        drop(tx);
        assert_eq!(rx.recv(), Some(1));
        assert_eq!(rx.recv(), None);
    }

    #[test]
    fn send_fails_after_receiver_drop() {
        let (tx, rx) = bounded::<i32>(1);
        drop(rx);
        assert_eq!(tx.send(1), Err(Closed));
    }

    #[test]
    fn drain_up_to_batches() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        let batch = rx.drain_up_to(4);
        assert_eq!(batch, vec![0, 1, 2, 3]);
        assert_eq!(rx.drain_up_to(100), vec![4, 5, 6, 7, 8, 9]);
    }

    #[test]
    fn blocking_send_unblocks_on_recv() {
        let (tx, rx) = bounded(1);
        tx.send(0).unwrap();
        let t = std::thread::spawn(move || tx.send(1).unwrap());
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(rx.recv(), Some(0));
        t.join().unwrap();
        assert_eq!(rx.recv(), Some(1));
    }
}
