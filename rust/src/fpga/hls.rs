//! HLS pipelined-loop latency algebra (paper eqs. 3 & 4, after [46]).
//!
//! Vitis HLS schedules a `#pragma HLS pipeline II=1` loop as
//!
//! ```text
//! PLL = (TC - 1) * II + PipelineDepth          (eq. 3)
//! TL  = PLL * outer_trip_count                 (eq. 4, un-pipelined outer)
//! ```
//!
//! FAMOUS's modules are all "outer loop un-pipelined, second loop pipelined
//! II=1, innermost fully unrolled" (Section VII), so every phase latency in
//! both the analytical model and the simulator reduces to instances of this
//! algebra.  Keeping it as an explicit type lets the simulator expose
//! per-loop cycle attributions and lets tests pin the algebra down.

/// One pipelined loop (the innermost *scheduled* loop after unrolling).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PipelinedLoop {
    /// Trip count (iterations of the pipelined loop).
    pub trip_count: u64,
    /// Initiation interval (cycles between iteration starts).
    pub ii: u64,
    /// Pipeline depth (cycles to drain one iteration).
    pub pipeline_depth: u64,
}

impl PipelinedLoop {
    pub fn new(trip_count: u64, ii: u64, pipeline_depth: u64) -> Self {
        assert!(ii >= 1, "II must be >= 1");
        assert!(pipeline_depth >= 1, "pipeline depth must be >= 1");
        PipelinedLoop { trip_count, ii, pipeline_depth }
    }

    /// Pipelined-loop latency, eq. 3.  A zero-trip loop costs nothing.
    pub fn latency(&self) -> u64 {
        if self.trip_count == 0 {
            return 0;
        }
        (self.trip_count - 1) * self.ii + self.pipeline_depth
    }
}

/// A pipelined loop enclosed by un-pipelined outer loops (eq. 4).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LoopNest {
    pub inner: PipelinedLoop,
    /// Product of all enclosing un-pipelined trip counts.
    pub outer_trips: u64,
}

impl LoopNest {
    pub fn new(inner: PipelinedLoop, outer_trips: u64) -> Self {
        LoopNest { inner, outer_trips }
    }

    /// Total latency, eq. 4: the outer loop re-fills the pipeline each
    /// iteration (no pragma on the outer loop, per Algorithm 1-3).
    pub fn latency(&self) -> u64 {
        self.inner.latency() * self.outer_trips
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq3_matches_hand_computation() {
        // (TC-1)*II + PD: 64 iterations, II=1, depth 13 -> 76.
        let l = PipelinedLoop::new(64, 1, 13);
        assert_eq!(l.latency(), 76);
    }

    #[test]
    fn eq4_scales_by_outer_trip() {
        let l = PipelinedLoop::new(64, 1, 13);
        assert_eq!(LoopNest::new(l, 64).latency(), 76 * 64);
    }

    #[test]
    fn ii_greater_than_one() {
        let l = PipelinedLoop::new(10, 3, 5);
        assert_eq!(l.latency(), 9 * 3 + 5);
    }

    #[test]
    fn zero_trip_costs_nothing() {
        let l = PipelinedLoop::new(0, 1, 10);
        assert_eq!(l.latency(), 0);
        assert_eq!(LoopNest::new(l, 100).latency(), 0);
    }

    #[test]
    fn single_trip_is_depth() {
        let l = PipelinedLoop::new(1, 1, 7);
        assert_eq!(l.latency(), 7);
    }

    #[test]
    fn latency_monotone_in_all_fields() {
        let base = PipelinedLoop::new(16, 1, 4).latency();
        assert!(PipelinedLoop::new(17, 1, 4).latency() > base);
        assert!(PipelinedLoop::new(16, 2, 4).latency() > base);
        assert!(PipelinedLoop::new(16, 1, 5).latency() > base);
    }
}
