//! Structural resource estimator, calibrated against Table I.
//!
//! The estimate is *structural*: each term is an identifiable piece of the
//! architecture (PE arrays, banked tiles, softmax, per-head control), with
//! coefficients fitted to the paper's four synthesized builds
//! (U55C @ TS∈{64,32,16}, U200 @ TS=64).  Fit residuals (EXPERIMENTS.md):
//!
//! * DSP  = h·(3·TS + d_k + SL + 170)                  (≤ ±6%, ≤1% on TS=64)
//! * BRAM = h·(2·TS + d_k + SL) + 832                  (≤ ±1%)
//! * LUT  = h·(22.3·TS² + 300·d_k + 469·SL) + 89_500   (≤ ±2%)
//! * FF   = h·345·TS + 491_000                         (≤ ±1%)
//!
//! Interpretation of the terms:
//! * `3·TS` DSP/head — the three QKV MAC chains, inner-unrolled over the
//!   tile width; `d_k` — QK_PM's unrolled dot product; `SL` — SV_PM's.
//! * `2·TS` BRAM/head — the three weight tiles + input tile after HLS bank
//!   quantization (fits the measured TS-sensitivity exactly).
//! * the quadratic LUT term is the TS-wide operand mux/routing fabric —
//!   this is the term that caps parallel heads (98% LUT on U55C) and is
//!   why the paper found h=8 (U55C) / h=6 (U200) to be the limits.
//!
//! `SL` here is the *synthesized* sequence length (the paper synthesizes
//! at SL=64 and reports constant resources for runtime SL up to 128 —
//! Table I tests 1–8; we adopt the same convention).

use super::device::Device;
use crate::config::Topology;
use crate::jsonlite::Json;

/// Calibrated coefficients (public so ablation benches can perturb them).
#[derive(Clone, Debug, PartialEq)]
pub struct ResourceModel {
    pub dsp_per_ts: f64,
    pub dsp_head_overhead: f64,
    pub bram_per_ts: f64,
    pub bram_fixed: f64,
    pub lut_ts_quad: f64,
    pub lut_per_dk: f64,
    pub lut_per_sl: f64,
    pub lut_fixed: f64,
    pub ff_per_ts: f64,
    pub ff_fixed: f64,
}

impl Default for ResourceModel {
    fn default() -> Self {
        ResourceModel {
            dsp_per_ts: 3.0,
            dsp_head_overhead: 170.0,
            bram_per_ts: 2.0,
            bram_fixed: 832.0,
            lut_ts_quad: 22.3,
            lut_per_dk: 300.0,
            lut_per_sl: 469.0,
            lut_fixed: 89_500.0,
            ff_per_ts: 345.0,
            ff_fixed: 491_000.0,
        }
    }
}

/// Predicted post-synthesis resource usage of one build.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResourceEstimate {
    pub dsp: u64,
    pub bram18k: u64,
    pub lut: u64,
    pub ff: u64,
}

impl ResourceEstimate {
    pub fn utilization(&self, dev: &Device) -> Utilization {
        Utilization {
            dsp_pct: self.dsp as f64 / dev.dsp as f64 * 100.0,
            bram_pct: self.bram18k as f64 / dev.bram18k as f64 * 100.0,
            lut_pct: self.lut as f64 / dev.lut as f64 * 100.0,
            ff_pct: self.ff as f64 / dev.ff as f64 * 100.0,
        }
    }

    /// Does the build fit the device? (LUT is the binding constraint in
    /// the paper; we check all four.)
    pub fn fits(&self, dev: &Device) -> bool {
        self.dsp <= dev.dsp && self.bram18k <= dev.bram18k && self.lut <= dev.lut && self.ff <= dev.ff
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("dsp", Json::from(self.dsp as f64)),
            ("bram18k", Json::from(self.bram18k as f64)),
            ("lut", Json::from(self.lut as f64)),
            ("ff", Json::from(self.ff as f64)),
        ])
    }
}

/// Percent-of-device view (Table I's parenthesized numbers).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Utilization {
    pub dsp_pct: f64,
    pub bram_pct: f64,
    pub lut_pct: f64,
    pub ff_pct: f64,
}

impl ResourceModel {
    /// Estimate resources for a build synthesized at `synth` (TS, h, d_k,
    /// SL are the synthesis-time maxima).
    pub fn estimate(&self, synth: &Topology) -> ResourceEstimate {
        let h = synth.heads as f64;
        let ts = synth.tile_size as f64;
        let dk = synth.d_k() as f64;
        let sl = synth.seq_len as f64;
        let dsp = h * (self.dsp_per_ts * ts + dk + sl + self.dsp_head_overhead);
        let bram = h * (self.bram_per_ts * ts + dk + sl) + self.bram_fixed;
        let lut = h * (self.lut_ts_quad * ts * ts + self.lut_per_dk * dk + self.lut_per_sl * sl)
            + self.lut_fixed;
        let ff = h * self.ff_per_ts * ts + self.ff_fixed;
        ResourceEstimate {
            dsp: dsp.round() as u64,
            bram18k: bram.round() as u64,
            lut: lut.round() as u64,
            ff: ff.round() as u64,
        }
    }

    /// Largest head count that fits `dev` at this (TS, d_model, SL) —
    /// the paper's "optimal number of attention heads" analysis
    /// (Section VI: 8 on U55C, 6 on U200 at TS=64).
    pub fn max_heads(&self, dev: &Device, d_model: usize, seq_len: usize, ts: usize) -> usize {
        let mut best = 0;
        for h in 1..=64 {
            if d_model % h != 0 {
                continue;
            }
            let t = Topology::new(seq_len, d_model, h, ts);
            if self.estimate(&t).fits(dev) {
                best = h;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pct_err(got: u64, want: u64) -> f64 {
        (got as f64 - want as f64).abs() / want as f64 * 100.0
    }

    /// The four synthesized builds from Table I, with the paper's numbers.
    fn paper_builds() -> Vec<(Topology, ResourceEstimate)> {
        vec![
            (
                Topology::new(64, 768, 8, 64),
                ResourceEstimate { dsp: 4157, bram18k: 3148, lut: 1_284_782, ff: 661_996 },
            ),
            (
                Topology::new(64, 768, 8, 32),
                ResourceEstimate { dsp: 3636, bram18k: 2636, lut: 746_769, ff: 587_337 },
            ),
            (
                Topology::new(64, 768, 8, 16),
                ResourceEstimate { dsp: 2996, bram18k: 2380, lut: 607_554, ff: 529_543 },
            ),
            (
                Topology::new(64, 768, 6, 64),
                ResourceEstimate { dsp: 3306, bram18k: 2740, lut: 1_048_022, ff: 625_983 },
            ),
        ]
    }

    #[test]
    fn calibration_within_tolerance() {
        let m = ResourceModel::default();
        for (topo, paper) in paper_builds() {
            let est = m.estimate(&topo);
            assert!(pct_err(est.dsp, paper.dsp) < 7.0, "DSP {topo}: {est:?} vs {paper:?}");
            assert!(pct_err(est.bram18k, paper.bram18k) < 2.0, "BRAM {topo}");
            assert!(pct_err(est.lut, paper.lut) < 3.0, "LUT {topo}");
            assert!(pct_err(est.ff, paper.ff) < 2.0, "FF {topo}");
        }
    }

    #[test]
    fn headline_build_tight() {
        // The TS=64 U55C build is the headline; hold it to ±1%.
        let m = ResourceModel::default();
        let est = m.estimate(&Topology::new(64, 768, 8, 64));
        assert!(pct_err(est.dsp, 4157) < 1.0, "dsp={}", est.dsp);
        assert!(pct_err(est.bram18k, 3148) < 1.0, "bram={}", est.bram18k);
        assert!(pct_err(est.lut, 1_284_782) < 1.0, "lut={}", est.lut);
        assert!(pct_err(est.ff, 661_996) < 1.0, "ff={}", est.ff);
    }

    #[test]
    fn reproduces_paper_max_heads() {
        // Section VI: "The optimal number of attention heads operating in
        // parallel was determined to be 8 and 6 ... on Alveo U55C and U200".
        let m = ResourceModel::default();
        assert_eq!(m.max_heads(&Device::alveo_u55c(), 768, 64, 64), 8);
        assert_eq!(m.max_heads(&Device::alveo_u200(), 768, 64, 64), 6);
    }

    #[test]
    fn lut_is_binding_constraint_on_u55c() {
        // Section VI: "Further DSP utilization was not feasible, as it
        // would have exceeded the capacity of LUTs."
        let m = ResourceModel::default();
        let dev = Device::alveo_u55c();
        let h9 = Topology::new(64, 768, 12, 64); // next divisor above 8
        let est = m.estimate(&h9);
        assert!(est.lut > dev.lut, "h=12 should blow LUTs");
        assert!(est.dsp < dev.dsp, "DSPs would still have headroom");
    }

    #[test]
    fn smaller_tile_uses_fewer_resources() {
        // Table I tests 9-10: reducing TS reduces every resource class.
        let m = ResourceModel::default();
        let e64 = m.estimate(&Topology::new(64, 768, 8, 64));
        let e32 = m.estimate(&Topology::new(64, 768, 8, 32));
        let e16 = m.estimate(&Topology::new(64, 768, 8, 16));
        assert!(e64.dsp > e32.dsp && e32.dsp > e16.dsp);
        assert!(e64.bram18k > e32.bram18k && e32.bram18k > e16.bram18k);
        assert!(e64.lut > e32.lut && e32.lut > e16.lut);
        assert!(e64.ff > e32.ff && e32.ff > e16.ff);
    }

    #[test]
    fn utilization_percentages_match_table1() {
        let m = ResourceModel::default();
        let u = m
            .estimate(&Topology::new(64, 768, 8, 64))
            .utilization(&Device::alveo_u55c());
        assert!((u.dsp_pct - 46.0).abs() < 2.0);
        assert!((u.bram_pct - 78.0).abs() < 2.0);
        assert!((u.lut_pct - 98.0).abs() < 2.5);
        assert!((u.ff_pct - 25.0).abs() < 2.0);
    }
}
