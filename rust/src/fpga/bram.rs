//! BRAM banking model.
//!
//! UltraScale+ block RAM comes in 18 Kb units with two ports.  HLS's
//! `array_partition` directive splits an array across banks so the
//! unrolled PEs can read operands in parallel; the paper leans on this
//! ("data required simultaneously by a DSP are stored in separate
//! BRAMs").  This model answers two questions the simulator and the
//! resource estimator need:
//!
//! * how many 18 Kb banks does an array of a given shape/partitioning
//!   consume, and
//! * does a parallel access pattern fit the ports (≤ 2 concurrent
//!   accesses per bank per cycle), or does it stall?

/// One 18 Kb, two-port block RAM.
pub const BRAM_BITS: u64 = 18 * 1024;
pub const PORTS_PER_BANK: u32 = 2;

/// A banked on-chip array (one logical HLS array after partitioning).
#[derive(Clone, Debug, PartialEq)]
pub struct BramBank {
    pub name: String,
    /// Logical element count (rows*cols).
    pub elems: u64,
    /// Element width in bits.
    pub width_bits: u32,
    /// Cyclic partition factor (number of physical banks).
    pub partition: u32,
}

impl BramBank {
    pub fn new(name: impl Into<String>, elems: u64, width_bits: u32, partition: u32) -> Self {
        assert!(partition > 0, "partition factor must be >= 1");
        BramBank { name: name.into(), elems, width_bits, partition }
    }

    /// 18 Kb units consumed, accounting for partition quantization: each
    /// partition rounds up to whole banks (this is where small tiles waste
    /// BRAM, visible in Table I's TS=16 row).
    pub fn banks18k(&self) -> u64 {
        let elems_per_part = self.elems.div_ceil(self.partition as u64);
        let bits_per_part = elems_per_part * self.width_bits as u64;
        let banks_per_part = bits_per_part.div_ceil(BRAM_BITS).max(1);
        banks_per_part * self.partition as u64
    }

    /// Cycles needed to satisfy `accesses` parallel reads in one II slot.
    /// With enough banks each access hits its own port: 1 cycle.  Port
    /// conflicts serialize.
    pub fn access_cycles(&self, accesses: u32) -> u32 {
        let ports = self.partition * PORTS_PER_BANK;
        accesses.div_ceil(ports).max(1)
    }

    /// True iff `accesses` simultaneous reads are conflict-free.
    pub fn conflict_free(&self, accesses: u32) -> bool {
        self.access_cycles(accesses) == 1
    }
}

/// The set of arrays one module instantiates (per attention head).
#[derive(Clone, Debug, Default)]
pub struct BramPool {
    pub banks: Vec<BramBank>,
}

impl BramPool {
    pub fn add(&mut self, bank: BramBank) -> &mut Self {
        self.banks.push(bank);
        self
    }

    pub fn total_banks18k(&self) -> u64 {
        self.banks.iter().map(BramBank::banks18k).sum()
    }

    /// Worst serialization factor across arrays for a pattern that reads
    /// `reads_per_array` operands from each array per cycle.
    pub fn worst_access_cycles(&self, reads_per_array: u32) -> u32 {
        self.banks.iter().map(|b| b.access_cycles(reads_per_array)).max().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_array_one_bank() {
        // 64 int8 elements, unpartitioned: 512 bits -> 1 bank.
        let b = BramBank::new("bias", 64, 8, 1);
        assert_eq!(b.banks18k(), 1);
    }

    #[test]
    fn partitioning_multiplies_banks() {
        // A (96 x 64) int8 weight tile = 6144 elems = 49152 bits = 3 banks
        // unpartitioned, but partitioned x64 -> 64 banks (quantization).
        let unpart = BramBank::new("w", 96 * 64, 8, 1);
        assert_eq!(unpart.banks18k(), 3);
        let part = BramBank::new("w", 96 * 64, 8, 64);
        assert_eq!(part.banks18k(), 64);
    }

    #[test]
    fn port_limits() {
        let b = BramBank::new("x", 4096, 8, 8); // 8 banks -> 16 ports
        assert!(b.conflict_free(16));
        assert!(!b.conflict_free(17));
        assert_eq!(b.access_cycles(32), 2);
        assert_eq!(b.access_cycles(1), 1);
    }

    #[test]
    fn pool_totals() {
        let mut p = BramPool::default();
        p.add(BramBank::new("a", 96 * 64, 8, 64));
        p.add(BramBank::new("b", 64 * 64, 8, 64));
        assert_eq!(p.total_banks18k(), 128);
        assert_eq!(p.worst_access_cycles(128), 1);
        assert_eq!(p.worst_access_cycles(129), 2);
    }

    #[test]
    fn partition_beyond_elems_still_counts_banks() {
        let b = BramBank::new("tiny", 4, 8, 16);
        assert_eq!(b.banks18k(), 16); // HLS still instantiates 16 banks
    }
}
