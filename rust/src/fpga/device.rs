//! UltraScale+ device inventories.
//!
//! Resource counts for the parts named in the paper's Tables I & IV.
//! Percent-utilization figures in Table I let us cross-check: U55C shows
//! 4157 DSPs = 46% (→ ~9024 total) and 1,284,782 LUTs = 98% (→ ~1.30M),
//! matching the published XCU55C (VU47P-class) and XCU200 (VU9P-class)
//! datasheets.

use crate::jsonlite::Json;

/// Static resource inventory of one FPGA part.
#[derive(Clone, Debug, PartialEq)]
pub struct Device {
    pub name: String,
    /// Part number as the paper cites it.
    pub part: String,
    pub dsp: u64,
    /// BRAM counted in 18 Kb units (Table I's "BRAMs 18k" column).
    pub bram18k: u64,
    pub lut: u64,
    pub ff: u64,
    /// Off-chip memory bandwidth in GB/s (HBM2 for U55C, DDR4 for U200).
    pub mem_bw_gbps: f64,
    /// Whether the part has HBM stacks (affects the AXI model's setup).
    pub has_hbm: bool,
}

impl Device {
    /// Alveo U55C (XCU55C-FSVH2892-2L-E) — the paper's primary platform.
    pub fn alveo_u55c() -> Device {
        Device {
            name: "alveo_u55c".into(),
            part: "XCU55C-FSVH2892-2L-E".into(),
            dsp: 9024,
            bram18k: 4032,
            lut: 1_303_680,
            ff: 2_607_360,
            mem_bw_gbps: 460.0, // 16 GB HBM2
            has_hbm: true,
        }
    }

    /// Alveo U200 (XCU200-FSGD2104-2-E) — the portability platform.
    pub fn alveo_u200() -> Device {
        Device {
            name: "alveo_u200".into(),
            part: "XCU200-FSGD2104-2-E".into(),
            dsp: 6840,
            bram18k: 4320,
            lut: 1_182_240,
            ff: 2_364_480,
            mem_bw_gbps: 77.0, // 4x DDR4-2400
            has_hbm: false,
        }
    }

    /// VU9P (Calabash [34]'s part) — used in Table IV context.
    pub fn vu9p() -> Device {
        Device {
            name: "vu9p".into(),
            part: "XCVU9P".into(),
            dsp: 6840,
            bram18k: 4320,
            lut: 1_182_240,
            ff: 2_364_480,
            mem_bw_gbps: 77.0,
            has_hbm: false,
        }
    }

    /// VU13P (Lu et al. [21]'s part).
    pub fn vu13p() -> Device {
        Device {
            name: "vu13p".into(),
            part: "XCVU13P".into(),
            dsp: 12_288,
            bram18k: 5376,
            lut: 1_728_000,
            ff: 3_456_000,
            mem_bw_gbps: 77.0,
            has_hbm: false,
        }
    }

    /// Alveo U250 (Ye et al. [35]'s part).
    pub fn alveo_u250() -> Device {
        Device {
            name: "alveo_u250".into(),
            part: "XCU250".into(),
            dsp: 12_288,
            bram18k: 5376,
            lut: 1_728_000,
            ff: 3_456_000,
            mem_bw_gbps: 77.0,
            has_hbm: false,
        }
    }

    /// VU37P (Li et al. [44]'s part, HBM).
    pub fn vu37p() -> Device {
        Device {
            name: "vu37p".into(),
            part: "XCVU37P".into(),
            dsp: 9024,
            bram18k: 4032,
            lut: 1_303_680,
            ff: 2_607_360,
            mem_bw_gbps: 460.0,
            has_hbm: true,
        }
    }

    pub fn by_name(name: &str) -> Option<Device> {
        match name {
            "alveo_u55c" | "u55c" => Some(Device::alveo_u55c()),
            "alveo_u200" | "u200" => Some(Device::alveo_u200()),
            "vu9p" => Some(Device::vu9p()),
            "vu13p" => Some(Device::vu13p()),
            "alveo_u250" | "u250" => Some(Device::alveo_u250()),
            "vu37p" => Some(Device::vu37p()),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj([
            ("name", Json::from(self.name.as_str())),
            ("part", Json::from(self.part.as_str())),
            ("dsp", Json::from(self.dsp as f64)),
            ("bram18k", Json::from(self.bram18k as f64)),
            ("lut", Json::from(self.lut as f64)),
            ("ff", Json::from(self.ff as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_utilization_cross_check() {
        // Table I: 4157 DSP = 46%, 3148 BRAM18k = 78%, 1,284,782 LUT = 98%
        // on U55C. Verify our inventory reproduces those percentages ±2pp.
        let d = Device::alveo_u55c();
        let pct = |used: u64, total: u64| used as f64 / total as f64 * 100.0;
        assert!((pct(4157, d.dsp) - 46.0).abs() < 2.0);
        assert!((pct(3148, d.bram18k) - 78.0).abs() < 2.0);
        assert!((pct(1_284_782, d.lut) - 98.0).abs() < 2.0);
        assert!((pct(661_996, d.ff) - 25.0).abs() < 2.0);
    }

    #[test]
    fn u200_utilization_cross_check() {
        // Table I tests 11-12: 3306 DSP = 48%, 2740 BRAM = 63%,
        // 1,048,022 LUT = 88% on U200.
        let d = Device::alveo_u200();
        let pct = |used: u64, total: u64| used as f64 / total as f64 * 100.0;
        assert!((pct(3306, d.dsp) - 48.0).abs() < 2.0);
        assert!((pct(2740, d.bram18k) - 63.0).abs() < 2.0);
        assert!((pct(1_048_022, d.lut) - 88.0).abs() < 2.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Device::by_name("u55c").unwrap().name, "alveo_u55c");
        assert_eq!(Device::by_name("u200").unwrap().name, "alveo_u200");
        assert!(Device::by_name("nope").is_none());
    }
}
