//! FPGA substrate: device inventories, BRAM banking, HLS loop-latency
//! algebra, and the structural resource estimator.
//!
//! These are the pieces of the Vitis/Vivado flow the paper's results
//! depend on; DESIGN.md §2 documents how each maps onto the simulator.

pub mod bram;
pub mod device;
pub mod hls;
pub mod resources;

pub use bram::{BramBank, BramPool};
pub use device::Device;
pub use hls::{LoopNest, PipelinedLoop};
pub use resources::{ResourceEstimate, ResourceModel, Utilization};
