//! Cross-language deterministic test vectors.
//!
//! Reimplements `python/compile/testdata.py` exactly: both sides generate
//! identical int8-grid matrices from the same LCG stream, so the rust
//! integration tests can feed the PJRT executables the very inputs the
//! python oracle used, comparing against the shipped `*.golden.bin`
//! without storing multi-megabyte weight dumps.

use crate::config::Topology;
use crate::rng::Lcg32;

/// Grid step of the shared int8 quantization grid (1/64).
pub const GRID_SCALE: f32 = 1.0 / 64.0;

/// Deterministic int8-grid values in `[-16, 16] * GRID_SCALE`.
pub fn lcg_vals(seed: u64, n: usize) -> Vec<f32> {
    let mut lcg = Lcg32::from_test_seed(seed);
    (0..n)
        .map(|_| {
            let v = ((lcg.next_state() >> 16) % 33) as i64 - 16;
            v as f32 * GRID_SCALE
        })
        .collect()
}

/// Row-major `rows x cols` matrix from stream `seed`.
pub fn gen_matrix(seed: u64, rows: usize, cols: usize) -> Vec<f32> {
    lcg_vals(seed, rows * cols)
}

/// All seven operands for one topology, in aot.py's `ARG_ORDER`
/// (x, wq, wk, wv, bq, bk, bv), each flattened row-major.
#[derive(Clone)]
pub struct MhaInputs {
    pub x: Vec<f32>,
    pub wq: Vec<f32>,
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
}

impl std::fmt::Debug for MhaInputs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MhaInputs({} elems)", self.elems())
    }
}

impl MhaInputs {
    pub fn generate(topo: &Topology) -> Self {
        let (sl, dm, h) = (topo.seq_len, topo.d_model, topo.heads);
        let dk = topo.d_k();
        MhaInputs {
            x: gen_matrix(1, sl, dm),
            wq: gen_matrix(2, h * dk, dm),
            wk: gen_matrix(3, h * dk, dm),
            wv: gen_matrix(4, h * dk, dm),
            bq: gen_matrix(5, h, dk),
            bk: gen_matrix(6, h, dk),
            bv: gen_matrix(7, h, dk),
        }
    }

    /// Total payload size in f32 elements (telemetry).
    pub fn elems(&self) -> usize {
        self.x.len()
            + self.wq.len()
            + self.wk.len()
            + self.wv.len()
            + self.bq.len()
            + self.bk.len()
            + self.bv.len()
    }

    /// Operand slices in the aot ARG_ORDER.
    pub fn in_order(&self) -> [&[f32]; 7] {
        [&self.x, &self.wq, &self.wk, &self.wv, &self.bq, &self.bk, &self.bv]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Topology;

    #[test]
    fn pinned_stream_matches_python() {
        let v = lcg_vals(1, 8);
        let expect: Vec<f32> = [-11f32, 4.0, 6.0, 11.0, -9.0, -10.0, 14.0, 15.0]
            .iter()
            .map(|x| x / 64.0)
            .collect();
        assert_eq!(v, expect);
    }

    #[test]
    fn values_on_grid_and_bounded() {
        for seed in [1, 2, 9] {
            for v in lcg_vals(seed, 512) {
                let grid = v / GRID_SCALE;
                assert_eq!(grid, grid.round());
                assert!(grid.abs() <= 16.0);
            }
        }
    }

    #[test]
    fn input_shapes() {
        let t = Topology::new(16, 256, 4, 64);
        let inp = MhaInputs::generate(&t);
        assert_eq!(inp.x.len(), 16 * 256);
        assert_eq!(inp.wq.len(), 4 * 64 * 256);
        assert_eq!(inp.bq.len(), 4 * 64);
    }

    #[test]
    fn different_seeds_different_streams() {
        assert_ne!(lcg_vals(1, 32), lcg_vals(2, 32));
    }
}
