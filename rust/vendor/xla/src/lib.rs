//! Offline stub of the `xla` (xla-rs) PJRT surface used by
//! `famous::runtime`.
//!
//! The real crate links `xla_extension` (PJRT CPU plugin), which the
//! offline image does not ship.  This stub keeps the runtime module —
//! and everything downstream of it — compiling unchanged; every entry
//! point fails at *runtime* with a clear message, and the rest of the
//! system falls back to the int8 simulator datapath (`SimBackend`),
//! which is the functional engine exercised by the test suite.
//!
//! The integration tests that need real PJRT skip themselves when the
//! `artifacts/` directory is absent, so the stub is never reached there
//! either.  Swapping the real crate back in is a Cargo.toml change.

use std::fmt;

/// Error type matching the `{e:?}` formatting call sites expect.
pub struct Error(pub String);

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT unavailable in this offline build (xla stub); \
         use the sim datapath backend"
    )))
}

/// Stub of `xla::PjRtClient`.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

/// Stub of `xla::HloModuleProto`.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Stub of `xla::XlaComputation`.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation(())
    }
}

/// Stub of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// Stub of `xla::PjRtBuffer`.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Stub of `xla::Literal`.
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_entry_point_reports_offline() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err:?}").contains("offline"));
        assert!(HloModuleProto::from_text_file("x.txt").is_err());
        let lit = Literal::vec1(&[1.0, 2.0]);
        assert!(lit.reshape(&[2]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }
}
