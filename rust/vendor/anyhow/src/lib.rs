//! Offline shim of the `anyhow` crate — the subset this repository uses.
//!
//! The container image has no crates.io access, so the real `anyhow`
//! cannot be fetched.  This crate reimplements the surface the codebase
//! relies on with identical semantics:
//!
//! * [`Error`]: type-erased error holding a message chain.  Like real
//!   `anyhow::Error` it deliberately does **not** implement
//!   `std::error::Error`, which is what makes the blanket
//!   `From<E: std::error::Error>` impl (and thus `?` on any std error)
//!   possible.
//! * [`Result<T>`] alias with `Error` as the default error type.
//! * [`anyhow!`] / [`bail!`] macros.
//! * [`Context`]: `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `Error::msg`, `Display`, and the `{:#}` alternate format that
//!   prints the whole cause chain (`outer: inner: root`).
//!
//! Swapping the real crate back in is a one-line Cargo.toml change; no
//! call site would alter.

use std::fmt;

/// Type-erased error: a chain of messages, outermost context first.
pub struct Error {
    /// `chain[0]` is the outermost message; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the cause chain, outermost first (subset of
    /// `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The root cause (innermost message).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated (anyhow's format).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().skip(1).enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

/// `?` on any std error converts into [`Error`], capturing its source
/// chain.  (Sound only because `Error` itself is not `std::error::Error`.)
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (subset of `anyhow::Context`).
pub trait Context<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `return Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_shows_outermost_only() {
        let e: Error = Err::<(), _>(io_err()).context("opening config").unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert_eq!(format!("{e:#}"), "opening config: file missing");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(inner().unwrap_err().to_string(), "file missing");
    }

    #[test]
    fn macros_format_and_bail() {
        fn f(n: i32) -> Result<i32> {
            if n < 0 {
                bail!("negative input {n}");
            }
            Err(anyhow!("always fails: {}", n))
        }
        assert_eq!(f(-1).unwrap_err().to_string(), "negative input -1");
        assert_eq!(f(3).unwrap_err().to_string(), "always fails: 3");
        let from_value = anyhow!(String::from("plain"));
        assert_eq!(from_value.to_string(), "plain");
    }

    #[test]
    fn with_context_lazily_wraps() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "x.json")).unwrap_err();
        assert_eq!(format!("{e:#}"), "reading x.json: file missing");
        assert_eq!(e.root_cause(), "file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        assert_eq!(v.context("missing field").unwrap_err().to_string(), "missing field");
        assert_eq!(Some(7u8).context("unused").unwrap(), 7);
    }

    #[test]
    fn error_msg_as_fn_item() {
        let r: std::result::Result<(), String> = Err("boom".into());
        let e = r.map_err(Error::msg).unwrap_err();
        assert_eq!(e.to_string(), "boom");
    }
}
