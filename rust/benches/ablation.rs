//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. load/compute overlap (gamma / double-buffering) — explains the
//!    TS=32/16 residuals in Table I;
//! 2. tile size — the resource/latency trade (Section VI);
//! 3. LUT softmax precision — numerics of the fabric's nonlinearity;
//! 4. batching policy — reconfiguration counts under mixed workloads.
//!
//!     cargo bench --bench ablation

use famous::analytical::LatencyModel;
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Request, Scheduler, SchedulerConfig};
use famous::fpga::ResourceModel;
use famous::report::{fmt_f, Table};
use famous::rng::XorShift64;
use famous::runtime::Backend;
use famous::runtime::SimBackend;
use famous::sim::{SimConfig, Simulator};
use famous::testdata::MhaInputs;

fn main() {
    overlap_ablation();
    tile_size_ablation();
    softmax_ablation();
    batching_ablation();
    println!("ablation OK");
}

/// 1. Overlap factor: residuals of tests 9-10 shrink as gamma -> 1,
///    evidence the real pipeline double-buffers tile loads.
fn overlap_ablation() {
    let rows = [
        (Topology::new(64, 768, 8, 64), 0.94, "test 1 (TS=64)"),
        (Topology::new(64, 768, 8, 32), 1.155, "test 9 (TS=32)"),
        (Topology::new(64, 768, 8, 16), 1.563, "test 10 (TS=16)"),
    ];
    let mut t = Table::new(
        "Ablation: load/compute overlap gamma (residual vs Table I)",
        &["row", "paper ms", "g=0", "resid", "g=0.5", "resid", "g=1", "resid"],
    );
    for (topo, paper, label) in &rows {
        let mut cells = vec![label.to_string(), fmt_f(*paper)];
        for gamma in [0.0, 0.5, 1.0] {
            let m = LatencyModel::with_overlap(gamma);
            let ms = m.predict(topo).total_ms();
            cells.push(fmt_f(ms));
            cells.push(format!("{:+.0}%", (ms - paper) / paper * 100.0));
        }
        t.row(cells);
    }
    print!("{}", t.render());
    // Claim: full overlap explains the small-tile rows far better.
    let g0 = LatencyModel::with_overlap(0.0);
    let g1 = LatencyModel::with_overlap(1.0);
    let t10 = Topology::new(64, 768, 8, 16);
    assert!(
        g1.residual_vs_ms(&t10, 1.563).abs() < g0.residual_vs_ms(&t10, 1.563).abs() / 2.0,
        "gamma=1 should at least halve the TS=16 residual"
    );

    // The simulator's double_buffer flag implements the same mechanism.
    let mut t2 = Table::new(
        "Simulator double-buffering (cycles)",
        &["TS", "sequential", "double-buffered", "saved"],
    );
    for ts in [64usize, 32, 16] {
        let topo = Topology::new(64, 768, 8, ts);
        let mut cfg = SimConfig::u55c();
        cfg.build.tile_size = ts;
        cfg.build.max_topology.tile_size = ts;
        let seq = Simulator::new(cfg.clone()).run_timing(&topo).unwrap().cycles;
        cfg.double_buffer = true;
        let dbuf = Simulator::new(cfg).run_timing(&topo).unwrap().cycles;
        t2.row(vec![
            ts.to_string(),
            seq.to_string(),
            dbuf.to_string(),
            format!("{:.0}%", (seq - dbuf) as f64 / seq as f64 * 100.0),
        ]);
        assert!(dbuf < seq);
    }
    print!("{}", t2.render());
}

/// 2. Tile size: smaller tiles free resources but cost latency (tests
///    9-10's trade, swept more finely).
fn tile_size_ablation() {
    let rm = ResourceModel::default();
    let lm = LatencyModel::default();
    let mut t = Table::new(
        "Ablation: tile size trade-off (d_model=768, h=8, SL=64)",
        &["TS", "DSP", "BRAM18k", "LUT", "latency ms", "GOPS"],
    );
    for ts in [16usize, 24, 32, 48, 64, 96, 128] {
        if 768 % ts != 0 {
            continue;
        }
        let topo = Topology::new(64, 768, 8, ts);
        let e = rm.estimate(&topo);
        let ms = lm.predict(&topo).total_ms();
        t.row(vec![
            ts.to_string(),
            e.dsp.to_string(),
            e.bram18k.to_string(),
            e.lut.to_string(),
            fmt_f(ms),
            fmt_f(famous::metrics::OpCount::paper_convention(&topo) / (ms * 1e-3)),
        ]);
    }
    print!("{}", t.render());
    // Monotone claims.
    let ms_at = |ts| lm.predict(&Topology::new(64, 768, 8, ts)).total_ms();
    assert!(ms_at(64) < ms_at(32) && ms_at(32) < ms_at(16));
}

/// 3. LUT softmax: functional error vs the exact-exponential datapath.
fn softmax_ablation() {
    let topo = Topology::new(64, 256, 8, 64);
    let inputs = MhaInputs::generate(&topo);
    let exact = SimBackend::new(SimConfig::u55c()).run_mha(&topo, &inputs).unwrap();
    let mut t = Table::new(
        "Ablation: LUT softmax precision (vs exact exponential)",
        &["LUT bits", "max |err|", "mean |err|"],
    );
    let mut prev = f32::INFINITY;
    for bits in [4u32, 6, 8, 10, 12] {
        let mut cfg = SimConfig::u55c();
        cfg.softmax_lut_bits = Some(bits);
        let got = SimBackend::new(cfg).run_mha(&topo, &inputs).unwrap();
        let errs: Vec<f32> = got.iter().zip(&exact).map(|(a, b)| (a - b).abs()).collect();
        let max = errs.iter().copied().fold(0f32, f32::max);
        let mean = errs.iter().sum::<f32>() / errs.len() as f32;
        t.row(vec![bits.to_string(), format!("{max:.2e}"), format!("{mean:.2e}")]);
        assert!(max <= prev * 1.5 + 1e-6, "error should not grow with bits");
        prev = max;
    }
    print!("{}", t.render());
}

/// 4. Batching policy: reconfigurations on random mixed request streams.
fn batching_ablation() {
    let topos = [
        Topology::new(64, 768, 8, 64),
        Topology::new(32, 768, 8, 64),
        Topology::new(64, 512, 8, 64),
        Topology::new(16, 768, 8, 64),
    ];
    let mut rng = XorShift64::new(42);
    let stream: Vec<Topology> = (0..200).map(|_| rng.pick(&topos).clone()).collect();

    let count = |policy, window| {
        let mut s = Scheduler::new(SchedulerConfig {
            max_batch: 16,
            policy,
            fairness_window: window,
        });
        for (i, topo) in stream.iter().enumerate() {
            s.push(Request::new(
                i as u64,
                topo.clone(),
                MhaInputs {
                    x: vec![], wq: vec![], wk: vec![], wv: vec![],
                    bq: vec![], bk: vec![], bv: vec![],
                },
            ));
        }
        let mut switches = 0;
        let mut last = None;
        while let Some(b) = s.next_batch() {
            if last.as_ref() != Some(&b[0].topology) {
                switches += 1;
                last = Some(b[0].topology.clone());
            }
        }
        switches
    };
    let mut t = Table::new(
        "Ablation: batching policy (200 mixed requests, 4 topologies)",
        &["policy", "fairness window", "topology switches"],
    );
    t.row(vec!["FIFO".into(), "-".into(), count(BatchPolicy::Fifo, 1).to_string()]);
    for w in [8usize, 32, 128] {
        t.row(vec![
            "GroupByTopology".into(),
            w.to_string(),
            count(BatchPolicy::GroupByTopology, w).to_string(),
        ]);
    }
    print!("{}", t.render());
    assert!(count(BatchPolicy::GroupByTopology, 128) < count(BatchPolicy::Fifo, 1));
}
