//! Table I regeneration: all 12 tests — latency/GOPS from the cycle-level
//! simulator (and the analytical model as a cross-check) against the
//! paper's published values, plus the resource columns from the
//! structural estimator.
//!
//!     cargo bench --bench table1

use famous::analytical::{row_is_reliable, LatencyModel, TABLE1};
use famous::config::Topology;
use famous::fpga::ResourceModel;
use famous::metrics::OpCount;
use famous::report::{fmt_f, Table};
use famous::sim::{SimConfig, Simulator};

fn sim_for(device: &str, ts: usize) -> Simulator {
    let mut cfg = if device == "u200" { SimConfig::u200() } else { SimConfig::u55c() };
    if ts != cfg.build.tile_size {
        cfg.build.tile_size = ts;
        cfg.build.max_topology.tile_size = ts;
    }
    Simulator::new(cfg)
}

fn main() {
    let model = LatencyModel::default();
    let mut t = Table::new(
        "Table I — latency & GOPS (sim vs paper; one constant set, fitted on test 1 only)",
        &["test", "topology", "TS", "dev", "paper ms", "sim ms", "model ms", "resid", "paper GOPS", "sim GOPS"],
    );
    let mut resids = Vec::new();
    for row in TABLE1 {
        let label = format!("{},{},{}", row.seq_len, row.d_model, row.heads);
        if row.d_model % row.heads != 0 {
            t.row(vec![
                row.test.to_string(), label, row.tile_size.to_string(), row.device.into(),
                fmt_f(row.latency_ms), "-".into(), "-".into(),
                "skipped: d_model % h != 0 (paper quirk)".into(),
                fmt_f(row.gops), "-".into(),
            ]);
            continue;
        }
        let topo = row.topology();
        let mut sim = sim_for(row.device, row.tile_size);
        let r = sim.run_timing(&topo).expect("admitted");
        let model_ms = model.predict(&topo).total_ms();
        let resid = (r.latency_ms - row.latency_ms) / row.latency_ms;
        if row_is_reliable(row.test) {
            resids.push(resid.abs());
        }
        let gops = OpCount::paper_convention(&topo) / (r.latency_ms * 1e-3);
        t.row(vec![
            row.test.to_string(),
            label,
            row.tile_size.to_string(),
            row.device.into(),
            fmt_f(row.latency_ms),
            fmt_f(r.latency_ms),
            fmt_f(model_ms),
            format!("{:+.1}%{}", resid * 100.0, if row_is_reliable(row.test) { "" } else { " (garbled row)" }),
            fmt_f(row.gops),
            fmt_f(gops),
        ]);
    }
    print!("{}", t.render());
    let median = {
        let mut r = resids.clone();
        r.sort_by(f64::total_cmp);
        r[r.len() / 2]
    };
    println!(
        "reliable rows: {} | median |resid| {:.1}% | max |resid| {:.1}% (tests 9-10: no-overlap reading; see ablation bench)",
        resids.len(),
        median * 100.0,
        resids.iter().copied().fold(0.0, f64::max) * 100.0
    );

    // Resource columns.
    let rm = ResourceModel::default();
    let mut rt = Table::new(
        "Table I — resources (structural estimate vs paper)",
        &["build", "DSP", "(paper)", "BRAM18k", "(paper)", "LUT", "(paper)", "FF", "(paper)"],
    );
    for (label, topo, p) in [
        ("U55C TS=64", Topology::new(64, 768, 8, 64), (4157u64, 3148u64, 1_284_782u64, 661_996u64)),
        ("U55C TS=32", Topology::new(64, 768, 8, 32), (3636, 2636, 746_769, 587_337)),
        ("U55C TS=16", Topology::new(64, 768, 8, 16), (2996, 2380, 607_554, 529_543)),
        ("U200 TS=64", Topology::new(64, 768, 6, 64), (3306, 2740, 1_048_022, 625_983)),
    ] {
        let e = rm.estimate(&topo);
        rt.row(vec![
            label.into(),
            e.dsp.to_string(), p.0.to_string(),
            e.bram18k.to_string(), p.1.to_string(),
            e.lut.to_string(), p.2.to_string(),
            e.ff.to_string(), p.3.to_string(),
        ]);
    }
    print!("{}", rt.render());

    // Shape assertions: the orderings Table I demonstrates.
    let ms = |sl, dm, h, ts, dev: &str| {
        sim_for(dev, ts).run_timing(&Topology::new(sl, dm, h, ts)).unwrap().latency_ms
    };
    assert!(ms(64, 768, 8, 64, "u55c") < ms(64, 768, 4, 64, "u55c"));
    assert!(ms(64, 768, 4, 64, "u55c") < ms(64, 768, 2, 64, "u55c"));
    assert!(ms(64, 256, 8, 64, "u55c") < ms(64, 512, 8, 64, "u55c"));
    assert!(ms(64, 768, 8, 64, "u55c") < ms(64, 768, 8, 32, "u55c"));
    assert!(ms(64, 768, 8, 32, "u55c") < ms(64, 768, 8, 16, "u55c"));
    assert!(ms(32, 768, 8, 64, "u55c") < ms(64, 768, 8, 64, "u55c"));
    println!("table1 shape assertions OK");
}
