//! Hot-path performance benches (EXPERIMENTS.md §Perf).
//!
//! Wall-clock micro/meso benches of the layers rust owns:
//! * simulator timing engine (must be O(phases), not O(cycles));
//! * functional int8 datapath (the fixed-point GEMM);
//! * PJRT execute path (artifact inference incl. literal marshalling);
//! * coordinator serving throughput over the sim datapath.
//!
//!     cargo bench --bench perf

use famous::accel::FamousAccelerator;
use famous::benchlib::{bench, black_box};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Coordinator, Request, SchedulerConfig};
use famous::fixed::{matmul_i32_tiled, FxMatrix, Quantizer};
use famous::report::{fmt_f, Table};
use famous::runtime::{Backend, Runtime, SimBackend};
use famous::sim::{SimConfig, Simulator};
use famous::testdata::MhaInputs;

fn main() {
    let topo = Topology::new(64, 768, 8, 64);
    let inputs = MhaInputs::generate(&topo);
    let mut t = Table::new("Hot-path wall-clock (this host)", &["path", "mean ms", "min ms", "note"]);

    // 1. Simulator timing engine.
    let s = bench(3, 50, || {
        let mut sim = Simulator::new(SimConfig::u55c());
        black_box(sim.run_timing(&topo).unwrap().cycles);
    });
    t.row(vec![
        "sim timing engine".into(),
        fmt_f(s.mean_ms),
        fmt_f(s.min_ms),
        "per request; O(phases)".into(),
    ]);

    // 2. Fixed-point GEMM (the functional datapath core): one head's QKV.
    let q = Quantizer::grid64();
    let x = FxMatrix::from_f32(&inputs.x, 64, 768, &q);
    let w = FxMatrix::from_f32(&inputs.wq[..96 * 768], 96, 768, &q);
    let macs = 64.0 * 768.0 * 96.0;
    let s = bench(3, 30, || {
        black_box(matmul_i32_tiled(&x, &w, 64));
    });
    t.row(vec![
        "int8 GEMM tiled (ref)".into(),
        fmt_f(s.mean_ms),
        fmt_f(s.min_ms),
        format!("{:.2} Gmac/s", macs / (s.min_ms * 1e-3) / 1e9),
    ]);
    let s = bench(3, 30, || {
        black_box(famous::fixed::matmul_i32_fast(&x, &w));
    });
    t.row(vec![
        "int8 GEMM fast (hot)".into(),
        fmt_f(s.mean_ms),
        fmt_f(s.min_ms),
        format!("{:.2} Gmac/s", macs / (s.min_ms * 1e-3) / 1e9),
    ]);

    // 3. Full functional datapath (8 heads).
    let s = bench(1, 10, || {
        let mut b = SimBackend::new(SimConfig::u55c());
        black_box(b.run_mha(&topo, &inputs).unwrap());
    });
    t.row(vec![
        "sim datapath full MHA".into(),
        fmt_f(s.mean_ms),
        fmt_f(s.min_ms),
        "int8 8-head (64,768)".into(),
    ]);

    // 4. PJRT execute, both artifact variants (when artifacts exist).
    if let Ok(mut rt) = Runtime::load("artifacts") {
        use famous::runtime::Variant;
        rt.run_mha(&topo, &inputs).unwrap(); // compile outside timing
        let s = bench(2, 20, || {
            black_box(rt.run_mha(&topo, &inputs).unwrap());
        });
        t.row(vec![
            "PJRT deploy (64,768,8)".into(),
            fmt_f(s.mean_ms),
            fmt_f(s.min_ms),
            "XLA-fused artifact; compiled-cache hit".into(),
        ]);
        if rt.run_mha_variant(&topo, &inputs, Variant::Pallas).is_ok() {
            let s = bench(1, 5, || {
                black_box(rt.run_mha_variant(&topo, &inputs, Variant::Pallas).unwrap());
            });
            t.row(vec![
                "PJRT pallas (64,768,8)".into(),
                fmt_f(s.mean_ms),
                fmt_f(s.min_ms),
                "interpret-grid HLO (while loops on XLA:CPU)".into(),
            ]);
        }
    } else {
        t.row(vec!["PJRT execute".into(), "-".into(), "-".into(), "no artifacts".into()]);
    }

    // 5. Coordinator throughput over the sim datapath.
    let s = bench(0, 3, || {
        let accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
        let mut coord = Coordinator::new(
            accel,
            SchedulerConfig {
                max_batch: 16,
                policy: BatchPolicy::GroupByTopology,
                fairness_window: 64,
            },
        );
        for i in 0..32u64 {
            let tp = if i % 2 == 0 {
                Topology::new(64, 768, 8, 64)
            } else {
                Topology::new(32, 768, 8, 64)
            };
            let inp = MhaInputs::generate(&tp);
            coord.submit(Request::new(i, tp, inp)).unwrap();
        }
        black_box(coord.serve_all().unwrap());
    });
    t.row(vec![
        "coordinator 32 reqs".into(),
        fmt_f(s.mean_ms),
        fmt_f(s.min_ms),
        format!("{:.0} req/s e2e", 32.0 / (s.min_ms * 1e-3)),
    ]);

    print!("{}", t.render());
    println!("perf OK");
}
