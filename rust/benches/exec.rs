//! Execute-path bench: the PR-2 allocating serial path vs the reusable
//! workspace vs head-parallel execution, over the Test-1 topology family
//! (d_model = 768, TS = 64; SL ∈ {16, 64, 128}, h ∈ {4, 8}), plus the
//! PR-5 long-SL sweep — fused tile-streaming attention vs the
//! materializing reference path over SL ∈ {128, 256, 512, 1024} with
//! wall time *and* peak workspace bytes per path — plus the PR-7
//! kernel-tier sweep (scalar oracle vs explicit-AVX2 vs AVX2+int8-GEMM,
//! DESIGN.md §14) over SL ∈ {64, 128, 256} — plus the PR-8 ABFT
//! integrity series (checksum verification on vs off, DESIGN.md §15)
//! over the same SL sweep, gated at <10% overhead at SL=256 — plus the
//! PR-10 int8-attention sweep (fused f32 vs int8 score GEMM + SV axpy,
//! DESIGN.md §17, win gated at SL ≥ 256) and the blocked-vs-flat int8
//! projection-GEMM series (cache blocking win gated at m ≥ 256).
//!
//! Every reference mode's output is asserted bit-identical to the
//! allocating serial reference before timing; the fused path is
//! asserted within its documented tolerance (DESIGN.md §12).  Hard
//! acceptance gates: on the headline Test-1 shape (SL=64, h=8) the
//! head-parallel workspace path must beat the PR-2 serial path, and the
//! fused path must beat the reference path outright at SL ≥ 256 while
//! retaining strictly fewer workspace bytes.
//!
//! Results are written machine-readable to `BENCH_exec.json` at the repo
//! root so the perf trajectory is tracked across PRs (EXPERIMENTS.md
//! §Perf documents the schema and the current numbers).
//!
//!     cargo bench --bench exec

use famous::benchlib::{bench, black_box};
use famous::cluster::{
    ClusterConfig, DesConfig, DeviceSpec, FleetSim, LoadGen, LoadGenConfig, QosPolicy,
    WorkloadProfile,
};
use famous::config::Topology;
use famous::exec::ThreadPool;
use famous::jsonlite::Json;
use famous::report::Table;
use famous::sim::{fused, ExecPath, KernelTier, PreparedWeights, SimConfig, SoftmaxKind, Workspace};
use famous::testdata::MhaInputs;

fn assert_bits(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length diverged");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: output diverged at element {i}");
    }
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let pool = ThreadPool::new(cores.max(2));
    let handle = pool.handle();
    let mut table = Table::new(
        format!("Execute path — serial vs workspace vs head-parallel ({cores} cores)"),
        &["topology", "alloc serial ms", "warm serial ms", "head-par ms", "lanes", "speedup"],
    );
    let mut results = Vec::new();

    for &(sl, h) in &[(16usize, 4usize), (16, 8), (64, 4), (64, 8), (128, 4), (128, 8)] {
        let topo = Topology::new(sl, 768, h, 64);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&SimConfig::u55c(), &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let lanes = h.min(cores);
        let (warmup, iters) = if sl >= 128 { (2, 10) } else { (3, 20) };

        // Reference output; every mode must reproduce it bit-for-bit.
        let want = prepared.execute(&x);

        // PR-2 path: allocate every intermediate per request.
        let alloc = bench(warmup, iters, || {
            black_box(prepared.execute(&x));
        });

        // Warm workspace, serial heads (zero allocations per request).
        let mut ws = Workspace::new();
        prepared.execute_into(&x, &mut ws);
        assert_bits(&want, ws.output(), "warm serial");
        let warm = bench(warmup, iters, || {
            prepared.execute_into(&x, &mut ws);
        });
        assert_bits(&want, ws.output(), "warm serial (post-bench)");

        // Head-parallel over the shared pool.
        let mut wsp = Workspace::new();
        prepared.execute_parallel(&x, &mut wsp, &handle, lanes);
        assert_bits(&want, wsp.output(), "head-parallel");
        let par = bench(warmup, iters, || {
            prepared.execute_parallel(&x, &mut wsp, &handle, lanes);
        });
        assert_bits(&want, wsp.output(), "head-parallel (post-bench)");

        // Acceptance: on the Test-1 headline shape the head-parallel
        // workspace path must beat the PR-2 allocating serial path.
        if sl == 64 && h == 8 && lanes > 1 {
            assert!(
                par.mean_ms < alloc.mean_ms,
                "head-parallel ({:.3} ms) did not beat the serial path ({:.3} ms)",
                par.mean_ms,
                alloc.mean_ms
            );
        }

        table.row(vec![
            format!("SL={sl} h={h}"),
            format!("{:.3}", alloc.mean_ms),
            format!("{:.3}", warm.mean_ms),
            format!("{:.3}", par.mean_ms),
            lanes.to_string(),
            format!("{:.2}x", alloc.mean_ms / par.mean_ms),
        ]);
        results.push(Json::obj([
            ("seq_len", Json::from(sl as f64)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(h as f64)),
            ("lanes", Json::from(lanes as f64)),
            ("serial_alloc_ms", Json::from(alloc.mean_ms)),
            ("serial_warm_ms", Json::from(warm.mean_ms)),
            ("head_parallel_ms", Json::from(par.mean_ms)),
            ("speedup_vs_alloc", Json::from(alloc.mean_ms / par.mean_ms)),
            ("bit_identical", Json::from(true)),
        ]));
    }

    print!("{}", table.render());
    println!("(outputs bit-identical across all modes; wall times are host-side)");

    // ---- Long-SL sweep: fused tile-streaming vs reference (PR 5) ----
    // Serial single-lane runs isolate the attention datapath; the
    // long-sequence build admits up to SL=1024.
    let mut long_table = Table::new(
        "Long-SL attention — reference (SL×SL) vs fused tile-streaming (SL×TS)",
        &["topology", "reference ms", "fused ms", "ref ws bytes", "fused ws bytes", "speedup"],
    );
    let mut long_results = Vec::new();
    for &sl in &[128usize, 256, 512, 1024] {
        let topo = Topology::new(sl, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&SimConfig::u55c_long(), &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let (warmup, iters) = match sl {
            128 => (2, 10),
            256 => (2, 10),
            512 => (1, 5),
            _ => (1, 3),
        };

        let mut ref_ws = Workspace::new();
        prepared.execute_into_path(&x, &mut ref_ws, ExecPath::Reference);
        let ref_bytes = ref_ws.footprint_bytes();
        let want = ref_ws.output().to_vec();
        let ref_t = bench(warmup, iters, || {
            prepared.execute_into_path(&x, &mut ref_ws, ExecPath::Reference);
        });
        assert_bits(&want, ref_ws.output(), "reference (post-bench)");

        let mut fused_ws = Workspace::new();
        prepared.execute_into_path(&x, &mut fused_ws, ExecPath::FusedTiled);
        let fused_bytes = fused_ws.footprint_bytes();
        assert_eq!(
            fused_ws.reference_score_capacity(),
            0,
            "SL={sl}: fused path materialized an SL×SL buffer"
        );
        let (diff, tol) = fused::assert_within_tolerance(
            SoftmaxKind::Exact,
            sl,
            &want,
            fused_ws.output(),
            &format!("SL={sl}"),
        );
        let fused_t = bench(warmup, iters, || {
            prepared.execute_into_path(&x, &mut fused_ws, ExecPath::FusedTiled);
        });

        assert!(
            fused_bytes < ref_bytes,
            "SL={sl}: fused workspace {fused_bytes} B not below reference {ref_bytes} B"
        );
        // Acceptance (ISSUE 5): the fused path must win wall-time from
        // SL=256 up — the regime the auto policy routes to it.  Gated
        // on min-of-iters: scheduling noise on shared CI runners only
        // ever inflates samples, so the minimum is the robust
        // comparison (the margin at the 256 boundary is ~10%).
        if sl >= 256 {
            assert!(
                fused_t.min_ms < ref_t.min_ms,
                "SL={sl}: fused (min {:.3} ms) did not beat reference (min {:.3} ms)",
                fused_t.min_ms,
                ref_t.min_ms
            );
        }

        long_table.row(vec![
            format!("SL={sl} h=8"),
            format!("{:.3}", ref_t.mean_ms),
            format!("{:.3}", fused_t.mean_ms),
            ref_bytes.to_string(),
            fused_bytes.to_string(),
            format!("{:.2}x", ref_t.mean_ms / fused_t.mean_ms),
        ]);
        long_results.push(Json::obj([
            ("seq_len", Json::from(sl as f64)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(8.0)),
            ("reference_ms", Json::from(ref_t.mean_ms)),
            ("fused_ms", Json::from(fused_t.mean_ms)),
            ("reference_workspace_bytes", Json::from(ref_bytes as f64)),
            ("fused_workspace_bytes", Json::from(fused_bytes as f64)),
            ("speedup_fused", Json::from(ref_t.mean_ms / fused_t.mean_ms)),
            ("max_abs_diff", Json::from(diff as f64)),
            ("tolerance", Json::from(tol as f64)),
        ]));
    }
    print!("{}", long_table.render());
    println!(
        "(fused asserted within documented tolerance; wall-time win asserted at SL>=256)"
    );

    // ---- Kernel-tier sweep: scalar vs AVX2 vs AVX2+int8 (PR 7) ----
    // Fused path, serial single-lane runs, so the inner kernels — not
    // the scheduler — are what gets timed.  Numerics asserted before
    // timing: SIMD tiers within the DESIGN.md §14 tier tolerance of the
    // scalar oracle, and the two AVX2 tiers bit-identical to each other
    // (exact integer projections feeding the same f32 code).  On hosts
    // without AVX2 every tier clamps to Scalar and must be bit-equal.
    let simd_available = KernelTier::Simd.is_available();
    // The bit-exact tiers only: simd-int8-attn changes attention-stage
    // numerics (dequantized int8 scores) and is swept in its own series
    // below against its own tolerance contract (DESIGN.md §17).
    const EXACT_TIERS: [KernelTier; 3] =
        [KernelTier::Scalar, KernelTier::Simd, KernelTier::SimdInt8];
    let mut tier_table = Table::new(
        format!("Kernel tiers — scalar vs simd vs simd-int8 (avx2={simd_available})"),
        &["topology", "scalar ms", "simd ms", "simd-int8 ms", "simd x", "int8 x"],
    );
    let mut tier_results = Vec::new();
    for &sl in &[64usize, 128, 256] {
        let topo = Topology::new(sl, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let (warmup, iters) = if sl >= 256 { (2, 8) } else { (3, 14) };
        let mut outs: Vec<Vec<f32>> = Vec::new();
        let mut stats = Vec::new();
        for tier in EXACT_TIERS {
            let prepared =
                PreparedWeights::prepare_with_tier(&SimConfig::u55c_long(), &topo, &inputs, tier);
            let x = prepared.quantize_input(&inputs.x);
            let mut ws = Workspace::new();
            prepared.execute_into_path(&x, &mut ws, ExecPath::FusedTiled);
            outs.push(ws.output().to_vec());
            stats.push(bench(warmup, iters, || {
                prepared.execute_into_path(&x, &mut ws, ExecPath::FusedTiled);
            }));
        }
        let mag = outs[0].iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let tol = fused::tier_tolerance(SoftmaxKind::Exact, sl, topo.d_k(), mag);
        for (tier, out) in EXACT_TIERS.into_iter().zip(&outs).skip(1) {
            for (i, (a, b)) in outs[0].iter().zip(out).enumerate() {
                assert!(
                    (a - b).abs() <= tol,
                    "SL={sl} {tier}: diverged from scalar at {i}: {a} vs {b} (tol {tol:.2e})"
                );
            }
        }
        if simd_available {
            assert_bits(&outs[1], &outs[2], &format!("SL={sl}: simd vs simd-int8"));
            // Acceptance (ISSUE 7): the AVX2 tiers must win wall-time on
            // AVX2 hosts once the kernels dominate the request (SL=256
            // here).  Min-of-iters for the same robustness argument as
            // the fused gate above.
            if sl >= 256 {
                for (name, t) in [("simd", &stats[1]), ("simd-int8", &stats[2])] {
                    assert!(
                        t.min_ms < stats[0].min_ms,
                        "SL={sl}: {name} (min {:.3} ms) did not beat scalar (min {:.3} ms)",
                        t.min_ms,
                        stats[0].min_ms
                    );
                }
            }
        } else {
            // Clamped tiers ran the scalar kernels: exact bit-identity.
            assert_bits(&outs[0], &outs[1], &format!("SL={sl}: clamped simd"));
            assert_bits(&outs[0], &outs[2], &format!("SL={sl}: clamped simd-int8"));
        }
        tier_table.row(vec![
            format!("SL={sl} h=8"),
            format!("{:.3}", stats[0].mean_ms),
            format!("{:.3}", stats[1].mean_ms),
            format!("{:.3}", stats[2].mean_ms),
            format!("{:.2}x", stats[0].mean_ms / stats[1].mean_ms),
            format!("{:.2}x", stats[0].mean_ms / stats[2].mean_ms),
        ]);
        tier_results.push(Json::obj([
            ("seq_len", Json::from(sl as f64)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(8.0)),
            ("scalar_ms", Json::from(stats[0].mean_ms)),
            ("simd_ms", Json::from(stats[1].mean_ms)),
            ("simd_int8_ms", Json::from(stats[2].mean_ms)),
            ("speedup_simd", Json::from(stats[0].mean_ms / stats[1].mean_ms)),
            ("speedup_simd_int8", Json::from(stats[0].mean_ms / stats[2].mean_ms)),
            ("simd_available", Json::from(simd_available)),
        ]));
    }
    print!("{}", tier_table.render());
    println!("(integer tiers bit-identical per DESIGN.md §14; AVX2 win asserted at SL=256)");

    // ---- Int8 attention: f32 fused vs int8 score/SV datapath (PR 10) ----
    // Both tiers stage identical blocked-i8 projections; what differs is
    // the attention stage — f32 score GEMM + f32 SV for simd-int8,
    // int8×int8→i32 tile scores dequantized into the online-softmax
    // absorb plus a dequantizing i8 SV axpy for simd-int8-attn — so the
    // speedup isolates the int8 attention datapath.  Numerics are
    // asserted against the per-request quantization bound
    // (`attn_quant_bound`, DESIGN.md §17) before timing; on hosts
    // without AVX2 both tiers clamp to Scalar and must be bit-equal.
    let mut attn_table = Table::new(
        format!("Int8 attention — fused f32 vs int8 scores+SV (avx2={simd_available})"),
        &["topology", "fused f32 ms", "int8-attn ms", "max |diff|", "tolerance", "speedup"],
    );
    let mut attn_results = Vec::new();
    for &sl in &[128usize, 256, 512] {
        let topo = Topology::new(sl, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let (warmup, iters) = if sl >= 512 { (1, 5) } else { (2, 8) };
        let f32_p = PreparedWeights::prepare_with_tier(
            &SimConfig::u55c_long(),
            &topo,
            &inputs,
            KernelTier::SimdInt8,
        );
        let attn_p = PreparedWeights::prepare_with_tier(
            &SimConfig::u55c_long(),
            &topo,
            &inputs,
            KernelTier::SimdInt8Attn,
        );
        let x = f32_p.quantize_input(&inputs.x);
        let mut ws_f32 = Workspace::new();
        f32_p.execute_into_path(&x, &mut ws_f32, ExecPath::FusedTiled);
        let mut ws_i8 = Workspace::new();
        attn_p.execute_into_path(&x, &mut ws_i8, ExecPath::FusedTiled);
        let tol = attn_p.attn_quant_bound(&x);
        let diff = ws_f32
            .output()
            .iter()
            .zip(ws_i8.output())
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        if simd_available {
            assert!(
                diff <= tol,
                "SL={sl}: int8-attn diverged {diff:.3e} beyond the quant bound {tol:.3e}"
            );
        } else {
            // Both tiers clamped to Scalar: exact bit-identity.
            assert_bits(ws_f32.output(), ws_i8.output(), &format!("SL={sl}: clamped int8-attn"));
        }
        let f32_t = bench(warmup, iters, || {
            f32_p.execute_into_path(&x, &mut ws_f32, ExecPath::FusedTiled);
        });
        let attn_t = bench(warmup, iters, || {
            attn_p.execute_into_path(&x, &mut ws_i8, ExecPath::FusedTiled);
        });
        // Acceptance (ISSUE 10): the int8 attention stage must win wall
        // time from SL=256 up on AVX2 hosts — min-of-iters for the same
        // robustness argument as the fused gate above.
        if simd_available && sl >= 256 {
            assert!(
                attn_t.min_ms < f32_t.min_ms,
                "SL={sl}: int8-attn (min {:.3} ms) did not beat fused f32 (min {:.3} ms)",
                attn_t.min_ms,
                f32_t.min_ms
            );
        }
        attn_table.row(vec![
            format!("SL={sl} h=8"),
            format!("{:.3}", f32_t.mean_ms),
            format!("{:.3}", attn_t.mean_ms),
            format!("{diff:.2e}"),
            format!("{tol:.2e}"),
            format!("{:.2}x", f32_t.mean_ms / attn_t.mean_ms),
        ]);
        attn_results.push(Json::obj([
            ("seq_len", Json::from(sl as f64)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(8.0)),
            ("fused_f32_ms", Json::from(f32_t.mean_ms)),
            ("int8_attn_ms", Json::from(attn_t.mean_ms)),
            ("speedup_int8_attn", Json::from(f32_t.mean_ms / attn_t.mean_ms)),
            ("max_abs_diff", Json::from(diff as f64)),
            ("tolerance", Json::from(tol as f64)),
            ("simd_available", Json::from(simd_available)),
        ]));
    }
    print!("{}", attn_table.render());
    println!("(int8-attn within per-request quant bound; AVX2 win asserted at SL>=256)");

    // ---- Blocked projection GEMM: flat vs packed block-major B (PR 10) ----
    // At the Test-1 width the projection B panel is 768×768 = 576 KB —
    // past L2 — so the flat driver re-streams all of B from L3 for
    // every A row.  The blocked driver packs B once (prepare-time in
    // the engine; here explicitly) into jc/pc panels and re-uses each
    // L2-resident KC×NC panel across MC rows of A.  Integer partial
    // sums commute, so the equivalence assert is exact `==`.
    let blk_results = {
        use famous::fixed::{matmul_i32_i8_blocked_into, matmul_i32_i8_into, PackedBi8};
        let mut blk_table = Table::new(
            "Blocked int8 GEMM — flat B vs packed block-major B (k=n=768)".to_string(),
            &["m", "flat ms", "blocked ms", "speedup"],
        );
        let mut blk_results = Vec::new();
        let (k, n) = (768usize, 768usize);
        // Deterministic full-range i8 operands from a tiny LCG.
        let mut state = 0x2545_f491u32;
        let mut next_i8 = move || {
            state = state.wrapping_mul(1_664_525).wrapping_add(1_013_904_223);
            (state >> 24) as u8 as i8
        };
        for &m in &[64usize, 256, 512] {
            let a8: Vec<i8> = (0..m * k).map(|_| next_i8()).collect();
            let b8: Vec<i8> = (0..n * k).map(|_| next_i8()).collect();
            let pb = PackedBi8::pack(&b8, k, n);
            let mut flat = vec![0i32; m * n];
            let mut blocked = vec![0i32; m * n];
            matmul_i32_i8_into(&a8, &b8, m, k, n, &mut flat);
            matmul_i32_i8_blocked_into(&a8, &pb, m, &mut blocked);
            assert_eq!(flat, blocked, "m={m}: blocked GEMM diverged from the flat driver");
            let (warmup, iters) = if m >= 512 { (2, 8) } else { (3, 12) };
            let flat_t = bench(warmup, iters, || {
                matmul_i32_i8_into(&a8, &b8, m, k, n, black_box(&mut flat));
            });
            let blk_t = bench(warmup, iters, || {
                matmul_i32_i8_blocked_into(&a8, &pb, m, black_box(&mut blocked));
            });
            // Acceptance (ISSUE 10): cache blocking must win once the A
            // sweep is tall enough to thrash B through L2 (m >= 256).
            if m >= 256 {
                assert!(
                    blk_t.min_ms < flat_t.min_ms,
                    "m={m}: blocked (min {:.3} ms) did not beat flat (min {:.3} ms)",
                    blk_t.min_ms,
                    flat_t.min_ms
                );
            }
            blk_table.row(vec![
                format!("{m}"),
                format!("{:.3}", flat_t.mean_ms),
                format!("{:.3}", blk_t.mean_ms),
                format!("{:.2}x", flat_t.mean_ms / blk_t.mean_ms),
            ]);
            blk_results.push(Json::obj([
                ("m", Json::from(m as f64)),
                ("k", Json::from(k as f64)),
                ("n", Json::from(n as f64)),
                ("flat_ms", Json::from(flat_t.mean_ms)),
                ("blocked_ms", Json::from(blk_t.mean_ms)),
                ("speedup_blocked", Json::from(flat_t.mean_ms / blk_t.mean_ms)),
                ("bit_identical", Json::from(true)),
            ]));
        }
        print!("{}", blk_table.render());
        println!("(blocked bit-identical to flat; blocking win asserted at m>=256)");
        blk_results
    };

    // ---- ABFT integrity overhead: checksum verify on vs off (PR 8) ----
    // The Huang–Abraham fold is priced at prepare; what this series
    // times is the per-request row verification on the serving path.
    // Verification only *reads* the accumulators, so verify-on output
    // must be bit-identical to verify-off — and the acceptance gate is
    // <10% wall-time overhead at SL=256 (DESIGN.md §15).
    let mut integ_table = Table::new(
        "ABFT integrity — checksum verify on vs off (fused path)",
        &["topology", "verify-off ms", "verify-on ms", "overhead %"],
    );
    let mut integ_results = Vec::new();
    for &sl in &[64usize, 128, 256] {
        let topo = Topology::new(sl, 768, 8, 64);
        let inputs = MhaInputs::generate(&topo);
        let (warmup, iters) = if sl >= 256 { (2, 8) } else { (3, 14) };
        let mut cfg_off = SimConfig::u55c_long();
        cfg_off.integrity_checks = false;
        let off_p = PreparedWeights::prepare(&cfg_off, &topo, &inputs);
        let on_p = PreparedWeights::prepare(&SimConfig::u55c_long(), &topo, &inputs);
        let x = on_p.quantize_input(&inputs.x);
        let mut ws_on = Workspace::new();
        on_p.execute_into_path(&x, &mut ws_on, ExecPath::FusedTiled);
        assert_eq!(ws_on.integrity_faults(), 0, "SL={sl}: clean weights flagged");
        let mut ws_off = Workspace::new();
        off_p.execute_into_path(&x, &mut ws_off, ExecPath::FusedTiled);
        assert_bits(ws_off.output(), ws_on.output(), &format!("SL={sl}: verify changed bits"));
        let off_t = bench(warmup, iters, || {
            off_p.execute_into_path(&x, &mut ws_off, ExecPath::FusedTiled);
        });
        let on_t = bench(warmup, iters, || {
            on_p.execute_into_path(&x, &mut ws_on, ExecPath::FusedTiled);
        });
        let overhead = on_t.min_ms / off_t.min_ms - 1.0;
        // Acceptance (ISSUE 8): verification rides in the accumulators'
        // O(m·k + m·n) shadow of the O(m·k·n) GEMMs — <10% at SL=256.
        if sl >= 256 {
            assert!(
                overhead < 0.10,
                "SL={sl}: ABFT verify overhead {:.1}% breaches the 10% budget \
                 (on min {:.3} ms vs off min {:.3} ms)",
                overhead * 100.0,
                on_t.min_ms,
                off_t.min_ms
            );
        }
        integ_table.row(vec![
            format!("SL={sl} h=8"),
            format!("{:.3}", off_t.mean_ms),
            format!("{:.3}", on_t.mean_ms),
            format!("{:.1}", (on_t.mean_ms / off_t.mean_ms - 1.0) * 100.0),
        ]);
        integ_results.push(Json::obj([
            ("seq_len", Json::from(sl as f64)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(8.0)),
            ("verify_off_ms", Json::from(off_t.mean_ms)),
            ("verify_on_ms", Json::from(on_t.mean_ms)),
            ("overhead_pct", Json::from((on_t.mean_ms / off_t.mean_ms - 1.0) * 100.0)),
            ("bit_identical", Json::from(true)),
        ]));
    }
    print!("{}", integ_table.render());
    println!("(verify-on bit-identical to verify-off; <10% overhead asserted at SL=256)");

    // ---- DES wall time: fixed seeded trace through the fleet sim ----
    // (ISSUE 9, DESIGN.md §16.)  One series point: how many wall-ms the
    // simulator needs for a fixed 100k-request bursty trace on a 4x
    // U55C fleet.  The regression gate watches this like any other wall
    // series — a slowdown here is a simulator-hot-path regression, and
    // drift in `served` under the fixed seed would surface as a failed
    // conservation assert.  Keyed by the mix's dominant shape; `lanes`
    // carries the fleet size.
    let des_results = {
        const DES_N: usize = 100_000;
        const DES_SEED: u64 = 0xbe0c_4de5;
        let mix: Vec<(Topology, f64)> = vec![
            (Topology::new(64, 768, 8, 64), 3.0),
            (Topology::new(32, 768, 8, 64), 2.0),
            (Topology::new(64, 512, 8, 64), 1.0),
        ];
        let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
        let mut workload = WorkloadProfile::default();
        for (t, share) in &mix {
            workload.push(t.clone(), *share);
        }
        let config = DesConfig {
            cluster: ClusterConfig { qos: QosPolicy::SlackEdf, ..ClusterConfig::default() },
            fused_service: false,
        };
        let mut sim = FleetSim::new(devices.clone(), &workload, config).expect("fleet boots");
        let mut gen =
            LoadGen::new(LoadGenConfig::bursty_preset(&devices, mix, 0.9, DES_SEED));
        let report = sim.run(&mut gen, DES_N);
        assert!(report.conserved(), "DES bench trace not conserved: {report:?}");
        println!(
            "des: {DES_N} requests in {:.1} ms wall ({:.0}x real time, {} served)",
            report.wall_ms,
            report.speedup(),
            report.served
        );
        vec![Json::obj([
            ("seq_len", Json::from(64.0)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(8.0)),
            ("lanes", Json::from(devices.len() as f64)),
            ("requests", Json::from(DES_N as f64)),
            ("wall_ms", Json::from(report.wall_ms)),
            ("virtual_ms", Json::from(report.virtual_ms)),
            ("speedup_virtual", Json::from(report.speedup())),
            ("served", Json::from(report.served as f64)),
            ("violation_rate", Json::from(report.violation_rate())),
        ])]
    };

    let out = Json::obj([
        ("bench", Json::from("exec")),
        ("unit", Json::from("ms_mean_wall")),
        ("measured", Json::from(true)),
        ("cores", Json::from(cores as f64)),
        ("results", Json::arr(results)),
        ("long_sl", Json::arr(long_results)),
        ("kernel_tiers", Json::arr(tier_results)),
        ("int8_attn", Json::arr(attn_results)),
        ("gemm_blocked", Json::arr(blk_results)),
        ("integrity", Json::arr(integ_results)),
        ("des", Json::arr(des_results)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_exec.json");
    std::fs::write(path, out.to_string() + "\n").expect("write BENCH_exec.json");
    println!("wrote {path}");
}
