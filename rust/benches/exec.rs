//! Execute-path bench: the PR-2 allocating serial path vs the reusable
//! workspace vs head-parallel execution, over the Test-1 topology family
//! (d_model = 768, TS = 64; SL ∈ {16, 64, 128}, h ∈ {4, 8}).
//!
//! Every mode's output is asserted bit-identical to the allocating
//! serial reference before timing, and on the headline Test-1 shape
//! (SL=64, h=8) the head-parallel workspace path must beat the PR-2
//! serial path outright.
//!
//! Results are written machine-readable to `BENCH_exec.json` at the repo
//! root so the perf trajectory is tracked across PRs (EXPERIMENTS.md
//! §Perf documents the schema and the current numbers).
//!
//!     cargo bench --bench exec

use famous::benchlib::{bench, black_box};
use famous::config::Topology;
use famous::exec::ThreadPool;
use famous::jsonlite::Json;
use famous::report::Table;
use famous::sim::{PreparedWeights, SimConfig, Workspace};
use famous::testdata::MhaInputs;

fn assert_bits(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length diverged");
    for (i, (a, b)) in want.iter().zip(got).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: output diverged at element {i}");
    }
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let pool = ThreadPool::new(cores.max(2));
    let handle = pool.handle();
    let mut table = Table::new(
        format!("Execute path — serial vs workspace vs head-parallel ({cores} cores)"),
        &["topology", "alloc serial ms", "warm serial ms", "head-par ms", "lanes", "speedup"],
    );
    let mut results = Vec::new();

    for &(sl, h) in &[(16usize, 4usize), (16, 8), (64, 4), (64, 8), (128, 4), (128, 8)] {
        let topo = Topology::new(sl, 768, h, 64);
        let inputs = MhaInputs::generate(&topo);
        let prepared = PreparedWeights::prepare(&SimConfig::u55c(), &topo, &inputs);
        let x = prepared.quantize_input(&inputs.x);
        let lanes = h.min(cores);
        let (warmup, iters) = if sl >= 128 { (2, 10) } else { (3, 20) };

        // Reference output; every mode must reproduce it bit-for-bit.
        let want = prepared.execute(&x);

        // PR-2 path: allocate every intermediate per request.
        let alloc = bench(warmup, iters, || {
            black_box(prepared.execute(&x));
        });

        // Warm workspace, serial heads (zero allocations per request).
        let mut ws = Workspace::new();
        prepared.execute_into(&x, &mut ws);
        assert_bits(&want, ws.output(), "warm serial");
        let warm = bench(warmup, iters, || {
            prepared.execute_into(&x, &mut ws);
        });
        assert_bits(&want, ws.output(), "warm serial (post-bench)");

        // Head-parallel over the shared pool.
        let mut wsp = Workspace::new();
        prepared.execute_parallel(&x, &mut wsp, &handle, lanes);
        assert_bits(&want, wsp.output(), "head-parallel");
        let par = bench(warmup, iters, || {
            prepared.execute_parallel(&x, &mut wsp, &handle, lanes);
        });
        assert_bits(&want, wsp.output(), "head-parallel (post-bench)");

        // Acceptance: on the Test-1 headline shape the head-parallel
        // workspace path must beat the PR-2 allocating serial path.
        if sl == 64 && h == 8 && lanes > 1 {
            assert!(
                par.mean_ms < alloc.mean_ms,
                "head-parallel ({:.3} ms) did not beat the serial path ({:.3} ms)",
                par.mean_ms,
                alloc.mean_ms
            );
        }

        table.row(vec![
            format!("SL={sl} h={h}"),
            format!("{:.3}", alloc.mean_ms),
            format!("{:.3}", warm.mean_ms),
            format!("{:.3}", par.mean_ms),
            lanes.to_string(),
            format!("{:.2}x", alloc.mean_ms / par.mean_ms),
        ]);
        results.push(Json::obj([
            ("seq_len", Json::from(sl as f64)),
            ("d_model", Json::from(768.0)),
            ("heads", Json::from(h as f64)),
            ("lanes", Json::from(lanes as f64)),
            ("serial_alloc_ms", Json::from(alloc.mean_ms)),
            ("serial_warm_ms", Json::from(warm.mean_ms)),
            ("head_parallel_ms", Json::from(par.mean_ms)),
            ("speedup_vs_alloc", Json::from(alloc.mean_ms / par.mean_ms)),
            ("bit_identical", Json::from(true)),
        ]));
    }

    print!("{}", table.render());
    println!("(outputs bit-identical across all modes; wall times are host-side)");

    let out = Json::obj([
        ("bench", Json::from("exec")),
        ("unit", Json::from("ms_mean_wall")),
        ("measured", Json::from(true)),
        ("cores", Json::from(cores as f64)),
        ("results", Json::arr(results)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_exec.json");
    std::fs::write(path, out.to_string() + "\n").expect("write BENCH_exec.json");
    println!("wrote {path}");
}
