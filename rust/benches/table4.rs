//! Table IV regeneration: FAMOUS vs prior FPGA accelerators, using the
//! paper's compute-only convention ("excluding the latency associated
//! with load and store operations").
//!
//! Our compute-only number comes from the simulator's phase trace (the
//! non-load phases); the prior-work rows are published datapoints.  The
//! claim to reproduce: FAMOUS is the lowest-latency / highest-GOPS entry
//! except Calabash (which excludes QKV computation from its own number).
//!
//!     cargo bench --bench table4

use famous::baselines::FPGA_TABLE4;
use famous::config::Topology;
use famous::metrics::OpCount;
use famous::report::{fmt_f, Table};
use famous::sim::{SimConfig, Simulator};

fn main() {
    let topo = Topology::new(64, 768, 8, 64);
    let mut sim = Simulator::new(SimConfig::u55c());
    let r = sim.run_timing(&topo).unwrap();
    let clock = sim.config.build.clock_hz;
    let compute_ms = r.trace.compute_only() as f64 / clock * 1e3;
    let ours_gops = OpCount::paper_convention(&topo) / (compute_ms * 1e-3);

    let mut t = Table::new(
        "Table IV — comparison with FPGA accelerators (compute-only attention latency)",
        &["work", "topology", "FPGA", "format", "method", "DSPs", "BRAMs", "GOPS", "latency ms", "ours ms"],
    );
    for p in FPGA_TABLE4 {
        t.row(vec![
            p.name.into(),
            format!("{},{},{}", p.seq_len, p.d_model, p.heads),
            p.fpga.into(),
            p.data_format.into(),
            p.method.into(),
            p.dsps.to_string(),
            if p.brams == 0 { "-".into() } else { p.brams.to_string() },
            fmt_f(p.gops),
            fmt_f(p.latency_ms),
            if p.name == "FAMOUS" { fmt_f(compute_ms) } else { "-".into() },
        ]);
    }
    print!("{}", t.render());
    println!(
        "our compute-only: {:.3} ms / {:.0} GOPS (paper: 0.494 ms / 623 GOPS)",
        compute_ms, ours_gops
    );

    // Shape assertions.
    assert!((compute_ms - 0.494).abs() / 0.494 < 0.10, "{compute_ms}");
    for p in FPGA_TABLE4.iter().filter(|p| p.name != "FAMOUS" && p.name != "Calabash") {
        assert!(
            compute_ms < p.latency_ms,
            "FAMOUS must beat {} ({} ms)",
            p.name,
            p.latency_ms
        );
    }
    let fastest_other = FPGA_TABLE4
        .iter()
        .filter(|p| p.name != "FAMOUS" && p.name != "Calabash")
        .map(|p| p.latency_ms)
        .fold(f64::INFINITY, f64::min);
    println!(
        "{:.2}x faster than the fastest prior FPGA work (paper claims 1.3x)",
        fastest_other / compute_ms
    );
    println!("table4 OK");
}
