//! Table III regeneration: FAMOUS (dense, FPGA) vs sparse ASIC
//! accelerators.  The ASIC numbers are published datapoints; our FAMOUS
//! row is recomputed from the simulator.  The claim to reproduce: dense
//! FAMOUS lands between A^3 and SpAtten despite forgoing sparsity, at
//! FPGA (not 1 GHz ASIC) clocks.
//!
//!     cargo bench --bench table3

use famous::baselines::ASIC_TABLE3;
use famous::config::Topology;
use famous::metrics::OpCount;
use famous::report::{fmt_f, Table};
use famous::sim::{SimConfig, Simulator};

fn main() {
    let topo = Topology::new(64, 768, 8, 64);
    let ms = Simulator::new(SimConfig::u55c()).run_timing(&topo).unwrap().latency_ms;
    let ours_gops = OpCount::paper_convention(&topo) / (ms * 1e-3);

    let mut t = Table::new(
        "Table III — comparison with ASIC accelerators",
        &["work", "sparse", "technology", "GOPS (paper)", "GOPS (ours)"],
    );
    for p in ASIC_TABLE3 {
        t.row(vec![
            p.name.into(),
            if p.sparse { "yes" } else { "no" }.into(),
            p.tech.into(),
            fmt_f(p.gops),
            if p.name == "FAMOUS" { fmt_f(ours_gops) } else { "-".into() },
        ]);
    }
    print!("{}", t.render());

    // Shape: our modeled FAMOUS reproduces the published 328 GOPS and the
    // orderings against the ASICs.
    assert!((ours_gops - 328.0).abs() < 5.0, "{ours_gops}");
    let gops_of = |n: &str| ASIC_TABLE3.iter().find(|p| p.name == n).unwrap().gops;
    assert!(ours_gops > gops_of("A^3"));
    assert!(ours_gops < gops_of("SpAtten"));
    assert!(ours_gops < gops_of("Sanger"));
    assert!(ours_gops < gops_of("SALO"));
    println!(
        "FAMOUS (dense, FPGA) at {ours_gops:.0} GOPS: above A^3 (221), below the sparse 55/45nm ASICs — Table III shape reproduced"
    );
}
