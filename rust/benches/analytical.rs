//! Section VII validation: analytical model vs cycle-level simulator vs
//! the paper's own predictions.
//!
//! The paper validates its model on two points (test 1: 0.98 vs 0.94 ms;
//! test 6: 1.9 vs 2.0 ms).  We validate on all twelve: the analytical
//! model and the simulator must agree exactly in sequential mode (shared
//! structure), and both sit within the documented residuals of the
//! measurements.
//!
//!     cargo bench --bench analytical

use famous::analytical::{LatencyModel, PAPER_PREDICTIONS, TABLE1};
use famous::report::{fmt_f, Table};
use famous::sim::{SimConfig, Simulator};

fn main() {
    let model = LatencyModel::default();
    let mut t = Table::new(
        "Analytical model vs simulator vs paper (Section VII)",
        &["test", "paper meas ms", "paper model ms", "our model ms", "our sim ms", "model==sim"],
    );
    for row in TABLE1 {
        if row.d_model % row.heads != 0 || row.device != "u55c" || row.tile_size != 64 {
            continue;
        }
        let topo = row.topology();
        let model_cc = model.predict(&topo).total_cycles();
        let sim_cc = Simulator::new(SimConfig::u55c()).run_timing(&topo).unwrap().cycles;
        let paper_pred = PAPER_PREDICTIONS
            .iter()
            .find(|(test, _)| *test == row.test)
            .map(|(_, ms)| fmt_f(*ms))
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            row.test.to_string(),
            fmt_f(row.latency_ms),
            paper_pred,
            fmt_f(model_cc as f64 / 400e6 * 1e3),
            fmt_f(sim_cc as f64 / 400e6 * 1e3),
            if model_cc == sim_cc { "exact".into() } else { format!("DIFF {model_cc} vs {sim_cc}") },
        ]);
        assert_eq!(model_cc, sim_cc, "test {}: analytical and sim must agree", row.test);
    }
    print!("{}", t.render());

    // Paper's two validation points, against our model.
    for (test, paper_ms) in PAPER_PREDICTIONS {
        let row = TABLE1.iter().find(|r| r.test == *test).unwrap();
        let ours = model.predict(&row.topology()).total_ms();
        let dev = (ours - paper_ms).abs() / paper_ms;
        println!(
            "test {test}: paper's model {paper_ms} ms, ours {ours:.3} ms ({:+.1}%)",
            dev * 100.0
        );
        assert!(dev < 0.15, "should track the paper's own predictions");
    }
    println!("analytical OK (model == sim on all comparable rows)");
}
