//! Program/execute pipeline bench: serial vs batched execution and
//! cold vs warm `ProgramCache` on one simulated device.
//!
//! Three modes over the same batch of same-topology requests (distinct
//! inputs, shared weights — the serving-a-model case):
//!
//! * **serial / cold** — cache capacity 0: every request re-runs the
//!   cycle-level timing sim and re-quantizes the weights, i.e. the
//!   pre-split behavior.
//! * **serial / warm** — default cache: one timing sim for the whole
//!   loop, but requests still execute one at a time.
//! * **batched / warm** — `FamousAccelerator::run_batch`: one timing
//!   sim, one weight preparation, requests fanned out over the worker
//!   pool.
//!
//! Outputs are asserted bit-identical across all three, and the
//! `timing_sims_run` counters are asserted (cold = one per request,
//! warm = exactly one).
//!
//!     cargo bench --bench pipeline

use famous::accel::{FamousAccelerator, ProgramCache};
use famous::config::Topology;
use famous::report::Table;
use famous::sim::SimConfig;
use famous::testdata::{gen_matrix, MhaInputs};
use std::time::Instant;

const BATCH: usize = 16;

fn requests(topo: &Topology) -> Vec<MhaInputs> {
    (0..BATCH as u64)
        .map(|i| {
            let mut inp = MhaInputs::generate(topo);
            inp.x = gen_matrix(1000 + i, topo.seq_len, topo.d_model);
            inp
        })
        .collect()
}

fn main() {
    let topo = Topology::new(64, 768, 8, 64);
    let reqs = requests(&topo);
    let mut t = Table::new(
        format!("Pipeline — {BATCH} requests of {topo}, sim datapath"),
        &["mode", "wall ms", "req/s", "timing sims", "speedup"],
    );

    // serial / cold: every invocation re-programs.
    let mut cold = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    cold.programs = ProgramCache::new(0);
    let t0 = Instant::now();
    let cold_outputs: Vec<Vec<f32>> =
        reqs.iter().map(|inp| cold.run(&topo, inp).expect("served").output).collect();
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(cold.timing_sims_run as usize, BATCH, "cold cache re-sims every request");

    // serial / warm: program once, execute one at a time.
    let mut warm = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let t0 = Instant::now();
    let warm_outputs: Vec<Vec<f32>> =
        reqs.iter().map(|inp| warm.run(&topo, inp).expect("served").output).collect();
    let warm_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(warm.timing_sims_run, 1, "warm cache programs once");

    // batched / warm: program once, execute in parallel.
    let mut batched = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let refs: Vec<&MhaInputs> = reqs.iter().collect();
    let t0 = Instant::now();
    let batch_reports = batched.run_batch(&topo, &refs).expect("served");
    let batch_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(batched.timing_sims_run, 1, "batch programs once");

    // Bit-identity across all three paths.
    for ((c, w), b) in cold_outputs.iter().zip(&warm_outputs).zip(&batch_reports) {
        assert_eq!(c, w, "warm-cache output diverged");
        assert_eq!(c, &b.output, "batched output diverged");
    }

    let row = |t: &mut Table, mode: &str, ms: f64, sims: u64| {
        t.row(vec![
            mode.into(),
            format!("{ms:.1}"),
            format!("{:.1}", BATCH as f64 / (ms * 1e-3)),
            sims.to_string(),
            format!("{:.2}x", cold_ms / ms),
        ]);
    };
    row(&mut t, "serial / cold cache", cold_ms, cold.timing_sims_run);
    row(&mut t, "serial / warm cache", warm_ms, warm.timing_sims_run);
    row(&mut t, "batched / warm cache", batch_ms, batched.timing_sims_run);
    print!("{}", t.render());
    println!("(outputs bit-identical across all three modes; wall times are host-side)");
}
