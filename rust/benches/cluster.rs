//! Cluster scaling bench: fixed offered load, 1 → 8 devices.
//!
//! The workload is a fixed batch of mixed-topology requests (the
//! flexibility mix of Table I shapes).  For each fleet size we measure
//! host wall time and report the *modeled* fabric metrics: cluster GOPS
//! over the makespan (the busiest device's fabric occupancy, counted as
//! Σ per-batch makespan now that a same-topology batch streams through
//! the fabric as one programmed pipeline — DESIGN.md §9), reconfigs per
//! request, and affinity hit rate.  Under batch-makespan accounting a
//! lone device amortizes whole batches, so fleet speedup saturates
//! earlier than the pre-batching near-linear curve; the win shows in
//! reconfigurations (flat: ≈ one per topology-device pair, not per
//! request) and in the per-device batch counts.  See benches/pipeline.rs
//! for the single-device serial-vs-batched and cold-vs-warm-cache view.
//!
//!     cargo bench --bench cluster

use famous::cluster::{Cluster, ClusterConfig, DeviceSpec, WorkloadProfile};
use famous::config::Topology;
use famous::coordinator::Request;
use famous::report::{fmt_f, Table};
use famous::testdata::MhaInputs;
use std::time::Instant;

const OFFERED_REQUESTS: usize = 64;

fn workload_mix() -> Vec<Topology> {
    vec![
        Topology::new(64, 768, 8, 64),
        Topology::new(32, 768, 8, 64),
        Topology::new(64, 512, 8, 64),
        Topology::new(128, 768, 8, 64),
    ]
}

fn main() {
    let mix = workload_mix();
    let mut t = Table::new(
        format!("Cluster scaling — {OFFERED_REQUESTS} mixed requests, U55C fleet"),
        &[
            "devices",
            "wall s",
            "makespan ms",
            "GOPS",
            "speedup",
            "reconf",
            "reconf/req",
            "affinity %",
        ],
    );
    let mut base_makespan = 0.0f64;
    for n in [1usize, 2, 4, 8] {
        let devices: Vec<DeviceSpec> = (0..n).map(DeviceSpec::u55c).collect();
        let cluster = Cluster::start(
            devices,
            &WorkloadProfile::uniform(&mix),
            ClusterConfig::default(),
        )
        .expect("cluster start");
        let t0 = Instant::now();
        let mut joins = Vec::new();
        for i in 0..OFFERED_REQUESTS {
            let h = cluster.handle();
            let topo = mix[i % mix.len()].clone();
            joins.push(std::thread::spawn(move || {
                let inputs = MhaInputs::generate(&topo);
                h.call(Request { id: i as u64, topology: topo, inputs }).expect("served")
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let fleet = cluster.shutdown();
        assert_eq!(fleet.totals.completed as usize, OFFERED_REQUESTS);
        let makespan = fleet.makespan_ms();
        if n == 1 {
            base_makespan = makespan;
        }
        t.row(vec![
            n.to_string(),
            format!("{wall:.2}"),
            fmt_f(makespan),
            fmt_f(fleet.cluster_gops()),
            if base_makespan > 0.0 {
                format!("{:.2}x", base_makespan / makespan)
            } else {
                "-".into()
            },
            fleet.reconfigurations().to_string(),
            format!("{:.3}", fleet.reconfigs_per_request()),
            format!("{:.0}", fleet.affinity_hit_rate() * 100.0),
        ]);
    }
    print!("{}", t.render());
    println!("(GOPS/makespan are modeled fabric quantities; wall s is host thread overhead)");
}
