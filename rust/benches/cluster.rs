//! Cluster bench: arrival-process load over 1 → 8 devices, plus the
//! QoS policy face-off.
//!
//! PR 1–3 replayed a uniform closed-loop batch (every client holds one
//! request in flight), which self-throttles to the service rate and
//! never exercises tails.  This bench drives the fleet with the seeded
//! *open-loop* generator instead ([`famous::cluster::loadgen`]): a
//! bursty MMPP at a fixed absolute rate on the virtual clock, mixed
//! priority classes with deadline budgets.  Small fleets run
//! supercritical and miss/shed; eight devices absorb the same offered
//! load comfortably — the serving-value curve the paper's GOPS numbers
//! imply but never show.
//!
//! The second table replays one identical trace through the PR-1
//! FIFO/affinity policy and the QoS `SlackEdf` + EDF policy on four
//! devices and asserts the acceptance criterion outright: at equal
//! offered load, EDF+slack yields strictly fewer SLO violations.
//!
//!     cargo bench --bench cluster

use famous::cluster::loadgen::rate_for_utilization;
use famous::cluster::{
    Arrival, Cluster, ClusterConfig, DeviceSpec, FleetStats, LoadGen, LoadGenConfig, QosOutcome,
    QosPolicy, WorkloadProfile,
};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Priority, SchedulerConfig};
use famous::report::{fmt_f, Table};
use std::time::Instant;

const OFFERED_REQUESTS: usize = 96;
const SEED: u64 = 0xbe57_10ad;

fn workload_mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(64, 768, 8, 64), 3.0),
        (Topology::new(32, 768, 8, 64), 2.0),
        (Topology::new(64, 512, 8, 64), 2.0),
        (Topology::new(128, 768, 8, 64), 1.0),
    ]
}

/// Replay one arrival trace through a fleet; returns the fleet report
/// and the host wall seconds.
fn replay(n_devices: usize, policy: QosPolicy, arrivals: &[Arrival]) -> (FleetStats, f64) {
    let mix = workload_mix();
    let scheduler = SchedulerConfig {
        max_batch: 8,
        policy: match policy {
            QosPolicy::SlackEdf => BatchPolicy::EdfWithinWindow,
            QosPolicy::Affinity => BatchPolicy::GroupByTopology,
        },
        fairness_window: 16,
    };
    let mut workload = WorkloadProfile::default();
    for (t, share) in &mix {
        workload.push(t.clone(), *share);
    }
    let devices: Vec<DeviceSpec> = (0..n_devices).map(DeviceSpec::u55c).collect();
    let cluster = Cluster::start(
        devices,
        &workload,
        ClusterConfig { scheduler, qos: policy, ..ClusterConfig::default() },
    )
    .expect("cluster start");
    let h = cluster.handle();
    let t0 = Instant::now();
    for (i, a) in arrivals.iter().enumerate() {
        // Served or explicitly shed — both are valid QoS outcomes here.
        let _outcome: QosOutcome = h.call_qos(a.materialize(i as u64)).expect("served");
    }
    let wall = t0.elapsed().as_secs_f64();
    (cluster.shutdown(), wall)
}

fn violations(f: &FleetStats) -> u64 {
    Priority::ALL.iter().map(|&p| f.totals.slo.violations(p)).sum()
}

fn main() {
    // Fixed offered load: what four devices would see at ρ = 0.9 —
    // heavy for 1–2 devices, comfortable for 8.  One seeded trace (the
    // shared bursty preset) is replayed by every configuration.
    let four: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    let rate_hz = rate_for_utilization(&four, &workload_mix(), 0.9);
    let arrivals = LoadGen::new(LoadGenConfig::bursty_preset(&four, workload_mix(), 0.9, SEED))
        .generate_n(OFFERED_REQUESTS);

    let mut t = Table::new(
        format!(
            "Cluster scaling — {OFFERED_REQUESTS} bursty requests at {rate_hz:.0} req/s offered"
        ),
        &[
            "devices",
            "wall s",
            "makespan ms",
            "GOPS",
            "miss %",
            "shed",
            "reconf/req",
            "affinity %",
        ],
    );
    // The 4-device SlackEdf run doubles as the face-off's EDF side (the
    // trace is deterministic, so re-running it would be pure waste).
    let mut edf4: Option<FleetStats> = None;
    for n in [1usize, 2, 4, 8] {
        let (fleet, wall) = replay(n, QosPolicy::SlackEdf, &arrivals);
        t.row(vec![
            n.to_string(),
            format!("{wall:.2}"),
            fmt_f(fleet.makespan_ms()),
            fmt_f(fleet.cluster_gops()),
            format!("{:.1}", fleet.totals.slo.overall_miss_rate() * 100.0),
            fleet.totals.slo.total_shed().to_string(),
            format!("{:.3}", fleet.reconfigs_per_request()),
            format!("{:.0}", fleet.affinity_hit_rate() * 100.0),
        ]);
        if n == 4 {
            edf4 = Some(fleet);
        }
    }
    print!("{}", t.render());
    println!("(GOPS/makespan/miss are modeled fabric quantities; wall s is host overhead)");

    // --- QoS face-off: one trace, two policies, four devices. ---------
    let edf = edf4.expect("4-device row ran");
    let (fifo, _) = replay(4, QosPolicy::Affinity, &arrivals);
    let mut q = Table::new(
        "QoS policy face-off — 4 devices, identical trace",
        &["policy", "miss %", "missed", "shed", "p99 high ms", "violations"],
    );
    for (name, f) in [("fifo/affinity", &fifo), ("edf+slack", &edf)] {
        q.row(vec![
            name.to_string(),
            format!("{:.1}", f.totals.slo.overall_miss_rate() * 100.0),
            f.totals.slo.total_missed().to_string(),
            f.totals.slo.total_shed().to_string(),
            fmt_f(f.totals.slo.sojourn[Priority::High.index()].percentile(99.0)),
            violations(f).to_string(),
        ]);
    }
    print!("{}", q.render());
    assert!(
        violations(&edf) < violations(&fifo),
        "EDF+slack must strictly beat FIFO/affinity at equal offered load: {} !< {}",
        violations(&edf),
        violations(&fifo)
    );
    println!(
        "EDF+slack violations {} < FIFO/affinity {} at equal offered load (asserted)",
        violations(&edf),
        violations(&fifo)
    );
}
