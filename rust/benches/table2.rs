//! Table II regeneration: FAMOUS vs CPU/GPU platforms.
//!
//! Published platform points are data (we cannot rerun a V100 here); the
//! bench reprints them with our modeled FAMOUS latency and recomputes the
//! speedups the paper claims (3.28× Xeon Gold, 2.6× V100, 1.17× E5).  In
//! addition it *measures* dense f32 MHA on this host (naive, blocked,
//! parallel) as a live general-purpose-platform comparator.
//!
//!     cargo bench --bench table2

use famous::baselines::{CpuAttention, FAMOUS_TABLE2, PLATFORMS_TABLE2};
use famous::config::Topology;
use famous::metrics::OpCount;
use famous::report::{fmt_f, fmt_ratio, Table};
use famous::sim::{SimConfig, Simulator};
use famous::testdata::MhaInputs;

fn famous_ms(topo: &Topology) -> f64 {
    Simulator::new(SimConfig::u55c()).run_timing(topo).unwrap().latency_ms
}

fn main() {
    let t768 = Topology::new(64, 768, 8, 64);
    let t512 = Topology::new(64, 512, 8, 64);
    let f768 = famous_ms(&t768);
    let f512 = famous_ms(&t512);

    let mut t = Table::new(
        "Table II — comparison with other acceleration platforms",
        &["platform", "topology", "GOP", "latency ms", "GOPS", "FAMOUS speedup (paper)", "(ours)"],
    );
    // Paper's published speedups for the matching FAMOUS topology.
    let paper_speedup = [1.17, 2.6, 3.28, 0.83];
    for (p, paper_sp) in PLATFORMS_TABLE2.iter().zip(paper_speedup) {
        let ours = if p.d_model == 768 { f768 } else { f512 };
        t.row(vec![
            p.name.into(),
            format!("{},{},{}", p.seq_len, p.d_model, p.heads),
            fmt_f(p.gop),
            fmt_f(p.latency_ms),
            fmt_f(p.gops),
            format!("{paper_sp:.2}x"),
            fmt_ratio(p.latency_ms, ours),
        ]);
    }
    for f in FAMOUS_TABLE2 {
        t.row(vec![
            format!("{} [model]", f.name),
            format!("{},{},{}", f.seq_len, f.d_model, f.heads),
            fmt_f(f.gop),
            fmt_f(if f.d_model == 768 { f768 } else { f512 }),
            fmt_f(OpCount::paper_convention(&Topology::new(f.seq_len, f.d_model, 8, 64))
                / (if f.d_model == 768 { f768 } else { f512 } * 1e-3)),
            "-".into(),
            "-".into(),
        ]);
    }
    print!("{}", t.render());

    // Paper-claim checks (ratios recomputed from our modeled latency).
    let xeon = &PLATFORMS_TABLE2[2];
    let v100 = &PLATFORMS_TABLE2[1];
    let e5 = &PLATFORMS_TABLE2[0];
    let sp_xeon = xeon.latency_ms / f512;
    let sp_v100 = v100.latency_ms / f512;
    let sp_e5 = e5.latency_ms / f768;
    println!(
        "speedups from our model: {:.2}x Xeon Gold (paper 3.28x), {:.2}x V100 (paper 2.6x), {:.2}x E5 (paper 1.17x)",
        sp_xeon, sp_v100, sp_e5
    );
    assert!((sp_xeon - 3.28).abs() < 0.15);
    assert!((sp_v100 - 2.6).abs() < 0.15);
    assert!((sp_e5 - 1.17).abs() < 0.05);

    // Live measured host baseline.
    let mut m = Table::new(
        "Measured dense f32 MHA on this host (live baseline)",
        &["kernel", "topology", "latency ms", "GOPS", "vs FAMOUS model"],
    );
    for (name, cpu) in [
        ("naive", CpuAttention::naive()),
        ("blocked-64", CpuAttention::blocked(64)),
        ("parallel", CpuAttention::parallel(64)),
    ] {
        for topo in [&t768, &t512] {
            let inputs = MhaInputs::generate(topo);
            // best of 3 runs
            let ms = (0..3)
                .map(|_| cpu.run(topo, &inputs).1)
                .fold(f64::INFINITY, f64::min);
            let gops = OpCount::paper_convention(topo) / (ms * 1e-3);
            let famous = if topo.d_model == 768 { f768 } else { f512 };
            m.row(vec![
                name.into(),
                format!("{},{},{}", topo.seq_len, topo.d_model, topo.heads),
                fmt_f(ms),
                fmt_f(gops),
                fmt_ratio(ms, famous),
            ]);
        }
    }
    print!("{}", m.render());
    println!("table2 OK");
}
