"""Deterministic cross-language test vectors.

The rust integration tests must feed the PJRT executables the *same*
inputs the python oracle used, without shipping multi-megabyte weight
dumps.  Both sides therefore generate inputs from the same closed-form
LCG-based formula (reimplemented in rust/src/testdata.rs); the artifact
bundle only stores the oracle *outputs*.

Values land on the int8 quantization grid scaled by 1/64 so the fixed-
point datapath, the float kernels, and the XLA executable all agree
bit-for-bit (every product/sum is an exact small integer in f32).
"""

import numpy as np

GRID_SCALE = 1.0 / 64.0  # int8 grid step; |x| <= 127/64 ~ 2


def _lcg_vals(seed, n):
    """Deterministic int8-grid values in [-16, 16]/64 via a 32-bit LCG.

    Small magnitudes keep QK^T products within the exact-f32 range for
    every topology in the registry.
    """
    state = np.uint64(seed * 2654435761 % (2**32) or 1)
    out = np.empty(n, dtype=np.float32)
    a = np.uint64(1664525)
    c = np.uint64(1013904223)
    mod = np.uint64(2**32)
    for i in range(n):
        state = (a * state + c) % mod
        out[i] = float((int(state) >> 16) % 33 - 16)  # [-16, 16]
    return out * GRID_SCALE


def gen_matrix(seed, rows, cols):
    return _lcg_vals(seed, rows * cols).reshape(rows, cols)


def gen_inputs(topo):
    """All operands for one topology, keyed by the aot entry signature."""
    sl, dm, h = topo.seq_len, topo.d_model, topo.heads
    d_k = topo.d_k
    x = gen_matrix(1, sl, dm)
    wq = gen_matrix(2, h * d_k, dm).reshape(h, d_k, dm)
    wk = gen_matrix(3, h * d_k, dm).reshape(h, d_k, dm)
    wv = gen_matrix(4, h * d_k, dm).reshape(h, d_k, dm)
    bq = gen_matrix(5, h, d_k)
    bk = gen_matrix(6, h, d_k)
    bv = gen_matrix(7, h, d_k)
    return x, wq, wk, wv, bq, bk, bv
