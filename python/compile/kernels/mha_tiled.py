"""Pallas kernels implementing the FAMOUS dataflow on TPU-shaped tiles.

Hardware adaptation (DESIGN.md §3): the paper streams (d_k × TS) weight
tiles from HBM into BRAM and accumulates partial products in on-chip
buffers; here the same schedule is expressed with a Pallas grid over the
reduction dimension and BlockSpecs that stage one (SL × TS) activation
block plus one (d_k × TS) weight block in VMEM per grid step, accumulating
into the output ref (which stays resident in VMEM because its index_map is
constant across the grid).

All kernels are lowered with ``interpret=True``: the image's PJRT client is
CPU-only, and real Mosaic lowering emits TPU custom-calls it cannot run.
Structure (BlockSpecs, grid, accumulation) is exactly what would lower to
Mosaic on hardware; see tpu_estimate.py for the VMEM/MXU projections.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU-only PJRT; see module docstring.


# --------------------------------------------------------------------------
# QKV projection module (QKV_PM, Algorithm 1 + Fig. 4 tiling)
# --------------------------------------------------------------------------

def _qkv_tile_kernel(x_ref, wq_ref, wk_ref, wv_ref, q_ref, k_ref, v_ref):
    """One grid step == one FAMOUS tile iteration: multiply the staged
    (SL × TS) activation block with the three staged (d_k × TS) weight
    blocks and accumulate into the resident Q/K/V buffers."""
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        q_ref[...] = jnp.zeros_like(q_ref)
        k_ref[...] = jnp.zeros_like(k_ref)
        v_ref[...] = jnp.zeros_like(v_ref)

    x = x_ref[...]
    # (SL,TS) @ (TS,d_k): contraction over the tile columns, exactly the
    # inner-unrolled MAC chain of Algorithm 1 lines 8-11.
    q_ref[...] += jnp.dot(x, wq_ref[...].T, preferred_element_type=jnp.float32)
    k_ref[...] += jnp.dot(x, wk_ref[...].T, preferred_element_type=jnp.float32)
    v_ref[...] += jnp.dot(x, wv_ref[...].T, preferred_element_type=jnp.float32)


def qkv_projection_tiled(x, wq, wk, wv, bq, bk, bv, ts):
    """Single-head tiled Q/K/V projection.

    x: (SL, d_model); w*: (d_k, d_model); b*: (d_k,).
    Returns (Q, K, V), each (SL, d_k).
    """
    sl, d_model = x.shape
    d_k = wq.shape[0]
    if d_model % ts != 0:
        raise ValueError(f"d_model={d_model} not a multiple of tile size {ts}")
    n_tiles = d_model // ts

    out_shape = jax.ShapeDtypeStruct((sl, d_k), jnp.float32)
    acc_spec = pl.BlockSpec((sl, d_k), lambda t: (0, 0))
    q, k, v = pl.pallas_call(
        _qkv_tile_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((sl, ts), lambda t: (0, t)),    # X column tile
            pl.BlockSpec((d_k, ts), lambda t: (0, t)),   # Wq tile
            pl.BlockSpec((d_k, ts), lambda t: (0, t)),   # Wk tile
            pl.BlockSpec((d_k, ts), lambda t: (0, t)),   # Wv tile
        ],
        out_specs=[acc_spec, acc_spec, acc_spec],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=INTERPRET,
    )(x, wq, wk, wv)
    # Bias add happens after the tile loop, as in the paper (biases are
    # streamed to registers while QKV_PM computes, then added once).
    return q + bq[None, :], k + bk[None, :], v + bv[None, :]


# --------------------------------------------------------------------------
# Score module (QK_PM, Algorithm 2) — QK^T, scale, softmax
# --------------------------------------------------------------------------

def _score_kernel(q_ref, k_ref, s_ref, *, scale):
    s = jnp.dot(q_ref[...], k_ref[...].T,
                preferred_element_type=jnp.float32) * scale
    # Row softmax fused in the same module, as the paper routes S directly
    # into the softmax unit before SV_PM.
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    s_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def attention_scores(q, k, scale):
    """Softmax(Q K^T * scale) for one head: (SL,d_k),(SL,d_k) -> (SL,SL)."""
    sl, d_k = q.shape
    return pl.pallas_call(
        functools.partial(_score_kernel, scale=float(scale)),
        out_shape=jax.ShapeDtypeStruct((sl, sl), jnp.float32),
        interpret=INTERPRET,
    )(q, k)


# --------------------------------------------------------------------------
# Attention-score module (SV_PM, Algorithm 3)
# --------------------------------------------------------------------------

def _sv_kernel(s_ref, v_ref, o_ref):
    o_ref[...] = jnp.dot(s_ref[...], v_ref[...],
                         preferred_element_type=jnp.float32)


def weighted_values(s, v):
    """S @ V for one head: (SL,SL),(SL,d_k) -> (SL,d_k)."""
    sl, d_k = v.shape
    return pl.pallas_call(
        _sv_kernel,
        out_shape=jax.ShapeDtypeStruct((sl, d_k), jnp.float32),
        interpret=INTERPRET,
    )(s, v)


# --------------------------------------------------------------------------
# Fused single-head attention (QK_PM + softmax + SV_PM in one kernel)
# --------------------------------------------------------------------------

def _fused_head_kernel(q_ref, k_ref, v_ref, o_ref, *, scale, causal):
    s = jnp.dot(q_ref[...], k_ref[...].T,
                preferred_element_type=jnp.float32) * scale
    if causal:
        # Decoder masking (eq. 1's Mask): row i attends to cols <= i.
        sl = s.shape[0]
        rows = jax.lax.broadcasted_iota(jnp.int32, (sl, sl), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (sl, sl), 1)
        s = jnp.where(cols <= rows, s, -1e9)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v_ref[...], preferred_element_type=jnp.float32)


def fused_attention_head(q, k, v, scale, causal=False):
    """softmax(Mask(QK^T·scale))·V in a single VMEM-resident kernel.  Used
    by the default model path: for FAMOUS-scale SL (≤ a few hundred) the
    whole (SL × SL) score tile fits comfortably in VMEM (tpu_estimate.py).
    ``causal=True`` gives the decoder's masked attention (Section II)."""
    sl, d_k = q.shape
    return pl.pallas_call(
        functools.partial(_fused_head_kernel, scale=float(scale),
                          causal=causal),
        out_shape=jax.ShapeDtypeStruct((sl, d_k), jnp.float32),
        interpret=INTERPRET,
    )(q, k, v)


# --------------------------------------------------------------------------
# Full multi-head attention assembled from the kernels
# --------------------------------------------------------------------------

def mha_tiled(x, wq, wk, wv, bq, bk, bv, ts, scale, fused=True,
              causal=False):
    """Multi-head attention with the FAMOUS schedule.

    x: (SL, d_model); w*: (h, d_k, d_model); b*: (h, d_k).
    Heads are vmapped (the hardware instantiates h parallel module sets).
    ``causal=True`` selects the decoder's masked attention (the unfused
    path has no mask support; fused is forced in that case).
    """
    def one_head(wq_h, wk_h, wv_h, bq_h, bk_h, bv_h):
        q, k, v = qkv_projection_tiled(x, wq_h, wk_h, wv_h,
                                       bq_h, bk_h, bv_h, ts)
        if fused or causal:
            return fused_attention_head(q, k, v, scale, causal=causal)
        s = attention_scores(q, k, scale)
        return weighted_values(s, v)

    heads = jax.vmap(one_head)(wq, wk, wv, bq, bk, bv)  # (h, SL, d_k)
    h, sl, d_k = heads.shape
    return jnp.transpose(heads, (1, 0, 2)).reshape(sl, h * d_k)
