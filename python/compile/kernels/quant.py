"""Int8 quantization helpers shared by the kernels and the reference.

FAMOUS quantizes activations and weights to 8-bit fixed point before they
enter the DSP48 MAC datapath (Table I: "8bit fixed").  We emulate that
datapath in float32: values are snapped to an int8 grid (symmetric,
per-tensor scale) and all subsequent MACs run in f32.  Products of two
int8-grid values are <= 2^14 and reduction fan-ins here are <= 768 terms,
so every intermediate is an exact integer below 2^24 — f32 arithmetic is
bit-exact integer arithmetic, matching the hardware's wide accumulator.
"""

import jax.numpy as jnp

INT8_MIN = -128.0
INT8_MAX = 127.0


def quantize(x, scale):
    """Snap ``x`` to the int8 grid with step ``scale`` (returns int values)."""
    return jnp.clip(jnp.round(x / scale), INT8_MIN, INT8_MAX)


def dequantize(q, scale):
    """Map int8 grid values back to real units."""
    return q * scale


def fake_quant(x, scale):
    """quantize -> dequantize: the value the fixed-point datapath sees."""
    return dequantize(quantize(x, scale), scale)


def pick_scale(x, bits=8):
    """Symmetric per-tensor scale covering the dynamic range of ``x``."""
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8)
    return amax / (2.0 ** (bits - 1) - 1.0)
