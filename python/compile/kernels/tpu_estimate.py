"""TPU performance projection for the Pallas kernels (DESIGN.md §8).

interpret=True gives CPU-numpy wallclock, which says nothing about TPU
performance; what *is* knowable statically is (a) the VMEM working set each
grid step stages (from the BlockSpecs) and (b) the MXU occupancy of each
matmul tile.  This module computes both so EXPERIMENTS.md §Perf can report
them per topology, and the kernel block shapes can be tuned against the
16 MiB/core VMEM budget and the 128×128 systolic array.
"""

from dataclasses import dataclass

VMEM_BYTES_PER_CORE = 16 * 1024 * 1024
MXU_DIM = 128  # systolic array is 128x128 (bf16 inputs, f32 accumulate)


def _ceil_div(a, b):
    return -(-a // b)


def _mxu_tiles(m, k, n):
    """Number of 128^3 MXU passes a (m,k)x(k,n) matmul occupies."""
    return _ceil_div(m, MXU_DIM) * _ceil_div(k, MXU_DIM) * _ceil_div(n, MXU_DIM)


def _mxu_utilization(m, k, n):
    """Useful MACs / MACs the occupied MXU passes could do."""
    ideal = m * k * n
    occupied = _mxu_tiles(m, k, n) * MXU_DIM ** 3
    return ideal / occupied


@dataclass
class KernelEstimate:
    """Static TPU projection for one kernel configuration."""
    name: str
    vmem_bytes: int          # resident working set per grid step
    vmem_frac: float         # fraction of the 16 MiB/core budget
    macs: int                # useful multiply-accumulates per invocation
    mxu_utilization: float   # useful / occupied MXU capacity
    fits_vmem: bool

    def row(self):
        return (f"{self.name:28s} vmem={self.vmem_bytes/2**20:7.3f} MiB "
                f"({self.vmem_frac*100:5.1f}%) mxu_util={self.mxu_utilization:5.3f} "
                f"fits={'yes' if self.fits_vmem else 'NO'}")


def estimate_qkv_tile(sl, d_model, h, ts, bytes_per_el=4):
    """qkv_projection_tiled: per grid step the kernel stages one (SL,TS) X
    block, three (d_k,TS) weight blocks, and keeps three (SL,d_k)
    accumulators resident."""
    d_k = d_model // h
    vmem = bytes_per_el * (sl * ts + 3 * d_k * ts + 3 * sl * d_k)
    macs = 3 * sl * ts * d_k * (d_model // ts)  # whole-call useful MACs
    util = _mxu_utilization(sl, ts, d_k)
    return KernelEstimate(
        name=f"qkv_tiled(sl={sl},d={d_model},h={h},ts={ts})",
        vmem_bytes=vmem, vmem_frac=vmem / VMEM_BYTES_PER_CORE,
        macs=macs, mxu_utilization=util,
        fits_vmem=vmem <= VMEM_BYTES_PER_CORE)


def estimate_fused_head(sl, d_model, h, bytes_per_el=4):
    """fused_attention_head: Q,K,V blocks + (SL,SL) score tile + output."""
    d_k = d_model // h
    vmem = bytes_per_el * (3 * sl * d_k + sl * sl + sl * d_k)
    macs = sl * d_k * sl + sl * sl * d_k  # QK^T + SV
    util = min(_mxu_utilization(sl, d_k, sl), _mxu_utilization(sl, sl, d_k))
    return KernelEstimate(
        name=f"fused_head(sl={sl},d={d_model},h={h})",
        vmem_bytes=vmem, vmem_frac=vmem / VMEM_BYTES_PER_CORE,
        macs=macs, mxu_utilization=util,
        fits_vmem=vmem <= VMEM_BYTES_PER_CORE)


def estimate_topology(sl, d_model, h, ts):
    """All kernel estimates for one FAMOUS topology."""
    return [estimate_qkv_tile(sl, d_model, h, ts),
            estimate_fused_head(sl, d_model, h)]


def report(topologies):
    lines = []
    for (sl, d, h, ts) in topologies:
        for est in estimate_topology(sl, d, h, ts):
            lines.append(est.row())
    return "\n".join(lines)


if __name__ == "__main__":
    print(report([(64, 768, 8, 64), (64, 512, 8, 64), (128, 768, 8, 64),
                  (64, 768, 12, 64), (256, 768, 8, 64)]))
