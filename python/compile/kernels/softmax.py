"""Standalone softmax kernels — the QK_PM tail of the paper.

Two variants:
  * ``softmax_exact``  — numerically-stable row softmax (reference grade).
  * ``softmax_lut``    — the paper's LUT realization: HLS synthesizes the
    exponential as a lookup table in LUTs/FFs.  We mirror that with a
    2^bits-entry table gathered inside the kernel, so the kernel's numerics
    match what the fabric computes (and match ref.lut_softmax exactly).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .mha_tiled import INTERPRET


def _softmax_kernel(s_ref, o_ref):
    s = s_ref[...]
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_exact(s):
    """Row softmax over the trailing axis of a 2-D score matrix."""
    return pl.pallas_call(
        _softmax_kernel,
        out_shape=jax.ShapeDtypeStruct(s.shape, jnp.float32),
        interpret=INTERPRET,
    )(s)


def make_exp_lut(bits=8, x_min=-8.0):
    """The exp table the fabric stores: 2^bits samples of exp over
    [x_min, 0], indexed by truncation."""
    n = 2 ** bits
    grid = x_min + jnp.arange(n, dtype=jnp.float32) * ((-x_min) / (n - 1))
    return jnp.exp(grid)


def _softmax_lut_kernel(s_ref, lut_ref, o_ref, *, bits, x_min):
    s = s_ref[...]
    m = jnp.max(s, axis=-1, keepdims=True)
    z = jnp.clip(s - m, x_min, 0.0)
    n = 2 ** bits
    step = (-x_min) / (n - 1)
    idx = jnp.floor((z - x_min) / step).astype(jnp.int32)
    idx = jnp.clip(idx, 0, n - 1)
    e = lut_ref[...][idx]
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_lut(s, bits=8, x_min=-8.0):
    """LUT softmax; bit-matches ref.lut_softmax(s, bits, x_min)."""
    lut = make_exp_lut(bits, x_min)
    return pl.pallas_call(
        functools.partial(_softmax_lut_kernel, bits=bits, x_min=x_min),
        out_shape=jax.ShapeDtypeStruct(s.shape, jnp.float32),
        interpret=INTERPRET,
    )(s, lut)
