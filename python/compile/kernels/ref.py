"""Pure-jnp oracle for every kernel in this package.

These are the ground-truth semantics the Pallas kernels (and, transitively,
the rust functional simulator) are validated against.  Shapes follow the
paper's notation:

    X  : (SL, d_model)            input sequence
    Wq : (h, d_k, d_model)        per-head projection, indexed [k][j] as in
    Wk : (h, d_k, d_model)        Algorithm 1 (i.e. Q = X @ Wq[h].T), where
    Wv : (h, d_k, d_model)        d_k = d_model / h
    Bq/Bk/Bv : (h, d_k)
    out: (SL, d_model)            heads concatenated along the feature dim

Equation 1 scales QK^T by 1/sqrt(d_k); Algorithm 2 line 9 divides by
d_model instead.  ``scale_mode`` selects between the two readings
("sqrt_dk" — eq. 1, default — or "d_model" — Algorithm 2).
"""

import math

import jax.numpy as jnp


def scale_factor(d_model, h, scale_mode="sqrt_dk"):
    """Python float (not a jnp value): shapes are static, and the kernels
    bake the scale in as a compile-time constant."""
    d_k = d_model // h
    if scale_mode == "sqrt_dk":
        return 1.0 / math.sqrt(float(d_k))
    if scale_mode == "d_model":
        return 1.0 / float(d_model)
    raise ValueError(f"unknown scale_mode {scale_mode!r}")


def qkv_projection(x, w, b):
    """Single-head projection: (SL,dm) @ (d_k,dm).T + (d_k,) -> (SL,d_k)."""
    return jnp.dot(x, w.T) + b[None, :]


def softmax(s):
    """Numerically-stable row softmax (the hardware uses a LUT variant)."""
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def lut_softmax(s, lut_bits=8, x_min=-8.0):
    """LUT softmax as synthesized by HLS: exp() is a 2^lut_bits-entry table
    over [x_min, 0] after max-subtraction.  Matches the hardware's
    quantized non-linearity; error vs exact softmax is bounded by the LUT
    step."""
    m = jnp.max(s, axis=-1, keepdims=True)
    z = jnp.clip(s - m, x_min, 0.0)
    # Snap the exp argument to the LUT grid (table indexed by truncation).
    step = (-x_min) / (2 ** lut_bits - 1)
    z_idx = jnp.floor((z - x_min) / step)
    z_q = x_min + z_idx * step
    e = jnp.exp(z_q)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def causal_mask(sl, neg=-1e9):
    """The decoder's Mask(·) of eq. 1: position i may attend to j <= i."""
    rows = jnp.arange(sl)[:, None]
    cols = jnp.arange(sl)[None, :]
    return jnp.where(cols <= rows, 0.0, neg).astype(jnp.float32)


def attention_head(q, k, v, scale, use_lut_softmax=False, causal=False):
    """Scaled dot-product attention for one head (Fig. 2), with the
    decoder's optional masking (Section II's Masked Attention)."""
    s = jnp.dot(q, k.T) * scale
    if causal:
        s = s + causal_mask(s.shape[0])
    p = lut_softmax(s) if use_lut_softmax else softmax(s)
    return jnp.dot(p, v)


def mha(x, wq, wk, wv, bq, bk, bv, scale_mode="sqrt_dk",
        use_lut_softmax=False, causal=False):
    """Full dense multi-head attention (eq. 1 & 2), heads concatenated."""
    h = wq.shape[0]
    d_model = x.shape[-1]
    scale = scale_factor(d_model, h, scale_mode)
    outs = []
    for i in range(h):
        q = qkv_projection(x, wq[i], bq[i])
        k = qkv_projection(x, wk[i], bk[i])
        v = qkv_projection(x, wv[i], bv[i])
        outs.append(attention_head(q, k, v, scale, use_lut_softmax, causal))
    return jnp.concatenate(outs, axis=-1)


def tiled_qkv_projection(x, w, b, ts):
    """Reference for the FAMOUS tiling (Fig. 4): reduce over column tiles of
    size ``ts``, accumulating partial products — must equal
    ``qkv_projection`` exactly in integer arithmetic."""
    d_model = x.shape[-1]
    assert d_model % ts == 0, "d_model must be a multiple of the tile size"
    acc = jnp.zeros((x.shape[0], w.shape[0]), dtype=jnp.float32)
    for t in range(d_model // ts):
        xs = x[:, t * ts:(t + 1) * ts]
        ws = w[:, t * ts:(t + 1) * ts]
        acc = acc + jnp.dot(xs, ws.T)
    return acc + b[None, :]


# --- Encoder extension (paper's stated future work: MHA + FFN + LN) ------

def layer_norm(x, gamma, beta, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return gamma * (x - mu) / jnp.sqrt(var + eps) + beta


def ffn(x, w1, b1, w2, b2):
    """Position-wise feed-forward network: two linear maps, ReLU between."""
    hmid = jnp.maximum(jnp.dot(x, w1) + b1, 0.0)
    return jnp.dot(hmid, w2) + b2


def encoder_block(x, params, scale_mode="sqrt_dk"):
    """Full encoder layer: MHA -> add&LN -> FFN -> add&LN (Fig. 1)."""
    a = mha(x, params["wq"], params["wk"], params["wv"],
            params["bq"], params["bk"], params["bv"], scale_mode)
    x1 = layer_norm(x + a, params["ln1_g"], params["ln1_b"])
    f = ffn(x1, params["w1"], params["b1"], params["w2"], params["b2"])
    return layer_norm(x1 + f, params["ln2_g"], params["ln2_b"])
