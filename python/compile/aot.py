"""AOT bridge: lower the L2 model to HLO text the rust runtime can load.

For every topology in the registry this emits

    artifacts/<name>.hlo.txt        HLO text of jit(mha_forward_quant)
    artifacts/<name>.golden.bin     oracle output (f32 LE), golden topologies
    artifacts/manifest.json         index the rust runtime reads

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run via ``make artifacts`` (from python/: ``python -m compile.aot``).
Python never runs again after this: the rust binary is self-contained.
"""

import argparse
import functools
import hashlib
import json
import os
import struct

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, testdata, topologies

ARG_ORDER = ["x", "wq", "wk", "wv", "bq", "bk", "bv"]


def arg_shapes(topo):
    sl, dm, h, d_k = topo.seq_len, topo.d_model, topo.heads, topo.d_k
    return {
        "x": (sl, dm),
        "wq": (h, d_k, dm), "wk": (h, d_k, dm), "wv": (h, d_k, dm),
        "bq": (h, d_k), "bk": (h, d_k), "bv": (h, d_k),
    }


def to_hlo_text(lowered):
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_topology(topo, use_pallas=True):
    """Lower one topology.

    Two variants share identical math (pytest + a rust integration test
    pin them to each other):

    * ``use_pallas=True`` — the Pallas kernels in interpret mode.  This is
      the kernel-structure artifact (what would lower to Mosaic on TPU);
      on the CPU PJRT backend its grid loops become HLO ``while`` ops,
      which XLA:CPU executes serially (~10x slower).
    * ``use_pallas=False`` — the same model through the pure-jnp path,
      which XLA fuses into flat GEMM kernels.  This is the deployment
      artifact the rust hot path executes (EXPERIMENTS.md §Perf).
    """
    shapes = arg_shapes(topo)
    specs = [jax.ShapeDtypeStruct(shapes[a], np.float32) for a in ARG_ORDER]

    def fn(*args):
        x, wq, wk, wv, bq, bk, bv = args
        from .kernels import quant
        fq = lambda a: quant.fake_quant(a, model.INT8_GRID_SCALE)
        out = model.mha_forward(fq(x), fq(wq), fq(wk), fq(wv), fq(bq),
                                fq(bk), fq(bv), tile_size=topo.tile_size,
                                use_pallas=use_pallas)
        return (out,)  # return_tuple interchange

    return jax.jit(fn).lower(*specs)


def write_golden(topo, out_dir):
    """Run the oracle on the deterministic testdata inputs and persist the
    output; the rust side regenerates the inputs from the same LCG."""
    args = testdata.gen_inputs(topo)
    out = np.asarray(model.mha_forward_quant(*args, tile_size=topo.tile_size),
                     dtype=np.float32)
    path = os.path.join(out_dir, f"{topo.name}.golden.bin")
    with open(path, "wb") as f:
        f.write(out.astype("<f4").tobytes())
    digest = hashlib.sha256(
        b"".join(np.asarray(a, dtype="<f4").tobytes() for a in args)
    ).hexdigest()
    return {"golden": os.path.basename(path),
            "golden_shape": list(out.shape),
            "inputs_sha256": digest}


def build(out_dir, golden=True, verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text-v1", "arg_order": ARG_ORDER,
                "grid_scale": testdata.GRID_SCALE, "entries": []}
    golden_names = {t.name for t in topologies.GOLDEN} if golden else set()
    for topo in topologies.TOPOLOGIES:
        topo.validate()
        # Deployment artifact: XLA-fused path (fast on CPU PJRT).
        hlo = to_hlo_text(lower_topology(topo, use_pallas=False))
        path = os.path.join(out_dir, f"{topo.name}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        # Kernel-structure artifact: Pallas interpret path (slow on CPU;
        # kept for cross-validation — see lower_topology docs).
        hlo_p = to_hlo_text(lower_topology(topo, use_pallas=True))
        path_p = os.path.join(out_dir, f"{topo.name}.pallas.hlo.txt")
        with open(path_p, "w") as f:
            f.write(hlo_p)
        entry = dict(topo.dict())
        entry["hlo"] = os.path.basename(path)
        entry["hlo_pallas"] = os.path.basename(path_p)
        entry["args"] = {a: list(s) for a, s in arg_shapes(topo).items()}
        if topo.name in golden_names:
            entry.update(write_golden(topo, out_dir))
        manifest["entries"].append(entry)
        if verbose:
            print(f"lowered {topo.name}: {len(hlo)} chars (+pallas variant)"
                  + (" (+golden)" if topo.name in golden_names else ""))
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"wrote {len(manifest['entries'])} artifacts to {out_dir}")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="artifact output directory")
    ap.add_argument("--no-golden", action="store_true",
                    help="skip golden-vector generation (faster)")
    args = ap.parse_args()
    build(args.out, golden=not args.no_golden)


if __name__ == "__main__":
    main()
