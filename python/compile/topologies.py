"""Topology registry: every (SL, d_model, h, TS) configuration the paper
evaluates (Table I tests 1-12, Table II comparison points) plus the
synthesis-time maxima.  aot.py lowers one artifact per entry; the rust
coordinator looks them up through artifacts/manifest.json.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class Topology:
    seq_len: int
    d_model: int
    heads: int
    tile_size: int

    @property
    def d_k(self):
        return self.d_model // self.heads

    @property
    def n_tiles(self):
        return self.d_model // self.tile_size

    @property
    def name(self):
        return (f"mha_sl{self.seq_len}_d{self.d_model}"
                f"_h{self.heads}_ts{self.tile_size}")

    def validate(self):
        if self.d_model % self.heads:
            raise ValueError(f"{self}: d_model must be divisible by heads")
        if self.d_model % self.tile_size:
            raise ValueError(f"{self}: d_model must be divisible by tile_size")

    def dict(self):
        d = asdict(self)
        d["name"] = self.name
        d["d_k"] = self.d_k
        d["n_tiles"] = self.n_tiles
        return d


# Table I — runtime-programmable tests on the TS=64 U55C build (tests 1-8),
# the TS=32/16 rebuilds (tests 9-10; same math, different schedule), and the
# U200 build (tests 11-12).  Table II adds (64,768,12) and (64,512,4).
TOPOLOGIES = [
    Topology(64, 768, 8, 64),    # test 1 / headline / Table II
    Topology(64, 768, 4, 64),    # test 2
    Topology(64, 768, 2, 64),    # test 3
    Topology(64, 512, 8, 64),    # test 4 / Table II
    Topology(64, 256, 8, 64),    # test 5
    Topology(128, 768, 8, 64),   # test 6
    Topology(32, 768, 8, 64),    # test 7
    Topology(16, 768, 8, 64),    # test 8
    Topology(64, 768, 8, 32),    # test 9  (TS resynthesis)
    Topology(64, 768, 8, 16),    # test 10 (TS resynthesis)
    Topology(64, 768, 6, 64),    # test 11 (U200)  -- 768/6 = 128
    Topology(64, 512, 6, 64),    # test 12 (U200)  -- 512/6 not integer! see note
    Topology(64, 768, 12, 64),   # Table II Intel E5 / Calabash topology
    Topology(64, 512, 4, 64),    # Table II V100 / P100 topology
]

# Note on test 12: the paper reports (SL=64, d_model=512, h=6) on U200, but
# 512/6 is not an integer d_k.  We follow eq. 2's constraint d_k = d_model/h
# and substitute h=8 for the functional artifact while keeping the paper's
# h=6 for the *timing* model (which only needs d_model/h as a rational
# workload ratio).  Recorded in EXPERIMENTS.md.
TOPOLOGIES = [t for t in TOPOLOGIES if t.d_model % t.heads == 0]

# Golden vectors are emitted for these (kept small to bound artifact size).
GOLDEN = [Topology(64, 768, 8, 64), Topology(16, 768, 8, 64),
          Topology(64, 256, 8, 64)]

# Synthesis-time maxima of the two builds in the paper (Section VI).
SYNTH_MAX = {
    "u55c_ts64": Topology(128, 768, 8, 64),
    "u200_ts64": Topology(128, 768, 6, 64),
}


def by_name(name):
    for t in TOPOLOGIES:
        if t.name == name:
            return t
    raise KeyError(name)
