"""Layer 2 — the jax model the accelerator executes.

`mha_forward` is the computation FAMOUS implements in fabric (eq. 1 & 2,
heads concatenated, no output projection — the paper's accelerator stops at
the attention score).  It is assembled from the Layer-1 Pallas kernels so a
single jax.jit lowering captures kernels + glue in one HLO module, which
aot.py serializes for the rust runtime.

`encoder_forward` is the paper's announced extension (full encoder block);
it reuses the same attention kernels and adds FFN + residual + LayerNorm.

The quantized path applies the same int8 fake-quantization the hardware's
8-bit datapath performs (see kernels/quant.py for why f32 emulation is
bit-exact here).
"""

import jax.numpy as jnp

from .kernels import mha_tiled, quant, ref

INT8_GRID_SCALE = 1.0 / 64.0  # matches testdata.GRID_SCALE / rust quantizer


def mha_forward(x, wq, wk, wv, bq, bk, bv, *, tile_size,
                scale_mode="sqrt_dk", fused=True, use_pallas=True,
                causal=False):
    """Dense MHA with the FAMOUS schedule.

    x: (SL, d_model); w*: (h, d_k, d_model); b*: (h, d_k) -> (SL, d_model).
    ``causal=True`` gives the decoder's masked attention (Section II).
    """
    d_model = x.shape[-1]
    h = wq.shape[0]
    scale = ref.scale_factor(d_model, h, scale_mode)
    if use_pallas:
        return mha_tiled.mha_tiled(x, wq, wk, wv, bq, bk, bv,
                                   tile_size, scale, fused=fused,
                                   causal=causal)
    return ref.mha(x, wq, wk, wv, bq, bk, bv, scale_mode, causal=causal)


def mha_forward_quant(x, wq, wk, wv, bq, bk, bv, *, tile_size,
                      in_scale=INT8_GRID_SCALE, scale_mode="sqrt_dk"):
    """8-bit-datapath MHA: operands snapped to the int8 grid first, exactly
    as the accelerator quantizes its HBM streams before the MACs."""
    fq = lambda a: quant.fake_quant(a, in_scale)
    return mha_forward(fq(x), fq(wq), fq(wk), fq(wv), fq(bq), fq(bk), fq(bv),
                       tile_size=tile_size, scale_mode=scale_mode)


def encoder_forward(x, params, *, tile_size, scale_mode="sqrt_dk"):
    """Full encoder block (future-work scope): Pallas MHA + FFN + LN."""
    a = mha_forward(x, params["wq"], params["wk"], params["wv"],
                    params["bq"], params["bk"], params["bv"],
                    tile_size=tile_size, scale_mode=scale_mode)
    x1 = ref.layer_norm(x + a, params["ln1_g"], params["ln1_b"])
    f = ref.ffn(x1, params["w1"], params["b1"], params["w2"], params["b2"])
    return ref.layer_norm(x1 + f, params["ln2_g"], params["ln2_b"])


def encoder_params_shape(sl, d_model, h, d_ff=None):
    """ShapeDtypeStructs for encoder_forward's parameter pytree."""
    import jax
    d_ff = d_ff or 4 * d_model
    d_k = d_model // h
    f32 = jnp.float32
    s = lambda *shape: jax.ShapeDtypeStruct(shape, f32)
    return {
        "wq": s(h, d_k, d_model), "wk": s(h, d_k, d_model),
        "wv": s(h, d_k, d_model),
        "bq": s(h, d_k), "bk": s(h, d_k), "bv": s(h, d_k),
        "ln1_g": s(d_model), "ln1_b": s(d_model),
        "w1": s(d_model, d_ff), "b1": s(d_ff),
        "w2": s(d_ff, d_model), "b2": s(d_model),
        "ln2_g": s(d_model), "ln2_b": s(d_model),
    }
