"""Softmax kernels (exact + LUT) vs oracle, and LUT error bounds."""

import hypothesis
import hypothesis.strategies as st
import numpy as np
import pytest

from compile import testdata
from compile.kernels import ref, softmax


def mk(seed, r, c):
    return testdata.gen_matrix(seed, r, c).astype(np.float32)


@pytest.mark.parametrize("sl", [4, 16, 64])
def test_softmax_exact_matches_ref(sl):
    s = mk(1, sl, sl) * 4.0
    got = np.asarray(softmax.softmax_exact(s))
    want = np.asarray(ref.softmax(s))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("bits", [6, 8, 10])
def test_softmax_lut_matches_ref_lut(bits):
    s = mk(2, 16, 16) * 4.0
    got = np.asarray(softmax.softmax_lut(s, bits=bits))
    want = np.asarray(ref.lut_softmax(s, lut_bits=bits))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_lut_error_shrinks_with_bits():
    """The LUT step bounds the softmax error: more bits -> closer to exact."""
    s = mk(3, 32, 32) * 6.0
    exact = np.asarray(ref.softmax(s))
    errs = [np.max(np.abs(np.asarray(softmax.softmax_lut(s, bits=b)) - exact))
            for b in (4, 6, 8, 10)]
    assert errs == sorted(errs, reverse=True) or errs[-1] < errs[0]
    assert errs[-1] < 5e-3  # 10-bit LUT is indistinguishable at int8 scale


def test_lut_softmax_is_row_stochastic():
    s = mk(4, 8, 8) * 10.0
    p = np.asarray(softmax.softmax_lut(s))
    np.testing.assert_allclose(p.sum(-1), np.ones(8), rtol=1e-6)
    assert (p >= 0).all()


@hypothesis.given(sl=st.sampled_from([2, 4, 8, 16]),
                  scale=st.floats(0.1, 16.0),
                  seed=st.integers(1, 100))
@hypothesis.settings(max_examples=20, deadline=None)
def test_softmax_invariances(sl, scale, seed):
    s = mk(seed, sl, sl) * scale
    p = np.asarray(softmax.softmax_exact(s))
    # shift invariance
    p2 = np.asarray(softmax.softmax_exact(s + 7.5))
    np.testing.assert_allclose(p, p2, rtol=1e-5, atol=1e-6)
    # monotonicity per row
    row = s[0]
    order = np.argsort(row, kind="stable")
    assert (np.diff(p[0][order]) >= -1e-7).all()


def test_exp_lut_table_shape():
    lut = np.asarray(softmax.make_exp_lut(bits=8, x_min=-8.0))
    assert lut.shape == (256,)
    assert np.isclose(lut[-1], 1.0)
    assert np.isclose(lut[0], np.exp(-8.0))
    assert (np.diff(lut) > 0).all()
