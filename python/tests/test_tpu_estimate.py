"""Static TPU projection sanity (DESIGN.md §8)."""

from compile.kernels import tpu_estimate as te


def test_headline_topology_fits_vmem():
    for est in te.estimate_topology(64, 768, 8, 64):
        assert est.fits_vmem, est.name
        assert est.vmem_frac < 0.1  # tiny tiles; far from the 16 MiB budget


def test_vmem_grows_with_tile_size():
    a = te.estimate_qkv_tile(64, 768, 8, 16).vmem_bytes
    b = te.estimate_qkv_tile(64, 768, 8, 64).vmem_bytes
    assert b > a


def test_mxu_util_improves_with_mxu_aligned_dims():
    small = te.estimate_fused_head(16, 768, 12)   # d_k=64, sl=16 -> padded
    big = te.estimate_fused_head(128, 1024, 8)    # 128-aligned everywhere
    assert big.mxu_utilization > small.mxu_utilization
    assert big.mxu_utilization == 1.0


def test_macs_count_matches_closed_form():
    est = te.estimate_qkv_tile(64, 768, 8, 64)
    assert est.macs == 3 * 64 * 768 * 96  # 3 projections, full reduction


def test_report_formats():
    out = te.report([(64, 768, 8, 64)])
    assert "qkv_tiled" in out and "fused_head" in out
