"""AOT pipeline tests: lowering, manifest schema, golden vectors,
testdata determinism (the cross-language contract with rust)."""

import hashlib
import json
import os

import numpy as np
import pytest

from compile import aot, model, testdata, topologies
from compile.topologies import Topology


def test_testdata_lcg_is_stable():
    """Pin the LCG stream: rust/src/testdata.rs reimplements this exactly.
    If this test ever needs updating, update the rust side in lockstep."""
    v = testdata._lcg_vals(1, 8)
    expect = np.float32([-11, 4, 6, 11, -9, -10, 14, 15]) / 64.0
    assert np.array_equal(v, expect)


def test_testdata_on_int8_grid():
    for seed in (1, 2, 9):
        v = testdata._lcg_vals(seed, 256) / testdata.GRID_SCALE
        assert np.array_equal(v, np.round(v))
        assert np.abs(v).max() <= 16


def test_gen_inputs_shapes():
    t = Topology(16, 256, 4, 64)
    x, wq, wk, wv, bq, bk, bv = testdata.gen_inputs(t)
    assert x.shape == (16, 256)
    assert wq.shape == wk.shape == wv.shape == (4, 64, 256)
    assert bq.shape == (4, 64)


def test_topology_registry_valid():
    for t in topologies.TOPOLOGIES:
        t.validate()
        assert t.d_k * t.heads == t.d_model
        assert t.n_tiles * t.tile_size == t.d_model
    assert topologies.by_name("mha_sl64_d768_h8_ts64").heads == 8
    with pytest.raises(KeyError):
        topologies.by_name("nope")


def test_lower_topology_produces_hlo_text():
    t = Topology(8, 128, 4, 32)
    hlo = aot.to_hlo_text(aot.lower_topology(t))
    assert hlo.startswith("HloModule")
    assert "f32[8,128]" in hlo  # input/output shape appears
    # no TPU custom-calls: interpret-mode pallas lowers to plain HLO
    assert "custom-call" not in hlo.lower() or "mosaic" not in hlo.lower()


def test_build_manifest_roundtrip(tmp_path, monkeypatch):
    small = [Topology(8, 128, 4, 32), Topology(4, 64, 2, 16)]
    monkeypatch.setattr(topologies, "TOPOLOGIES", small)
    monkeypatch.setattr(topologies, "GOLDEN", [small[0]])
    man = aot.build(str(tmp_path), verbose=False)
    with open(tmp_path / "manifest.json") as f:
        loaded = json.load(f)
    assert loaded["arg_order"] == aot.ARG_ORDER
    assert len(loaded["entries"]) == 2
    e0 = next(e for e in loaded["entries"] if e["name"] == small[0].name)
    assert (tmp_path / e0["hlo"]).exists()
    assert (tmp_path / e0["golden"]).exists()
    # golden payload: f32-LE of the quant forward on testdata inputs
    got = np.frombuffer((tmp_path / e0["golden"]).read_bytes(), "<f4")
    want = np.asarray(model.mha_forward_quant(
        *testdata.gen_inputs(small[0]), tile_size=32)).ravel()
    assert np.array_equal(got, want)
    # inputs hash matches regeneration
    digest = hashlib.sha256(b"".join(
        np.asarray(a, "<f4").tobytes()
        for a in testdata.gen_inputs(small[0]))).hexdigest()
    assert e0["inputs_sha256"] == digest


@pytest.mark.skipif(not os.path.exists(
    os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="run `make artifacts` first")
def test_shipped_manifest_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.json")) as f:
        man = json.load(f)
    names = {e["name"] for e in man["entries"]}
    assert "mha_sl64_d768_h8_ts64" in names  # the headline topology
    for e in man["entries"]:
        assert os.path.exists(os.path.join(root, e["hlo"])), e["name"]
        if "golden" in e:
            n = np.prod(e["golden_shape"])
            sz = os.path.getsize(os.path.join(root, e["golden"]))
            assert sz == 4 * n
