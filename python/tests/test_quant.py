"""Quantization helper properties (the int8 datapath contract)."""

import hypothesis
import hypothesis.strategies as st
import numpy as np

from compile.kernels import quant


@hypothesis.given(st.lists(st.floats(-100, 100), min_size=1, max_size=64),
                  st.floats(1e-3, 2.0))
@hypothesis.settings(max_examples=50, deadline=None)
def test_quantize_range(vals, scale):
    q = np.asarray(quant.quantize(np.float32(vals), scale))
    assert (q >= -128).all() and (q <= 127).all()
    assert np.array_equal(q, np.round(q))  # integers on the grid


@hypothesis.given(st.floats(1e-3, 2.0), st.integers(-128, 127))
@hypothesis.settings(max_examples=50, deadline=None)
def test_fake_quant_idempotent(scale, level):
    """Values already on the grid are fixed points of fake_quant."""
    x = np.float32(level) * scale
    y = np.asarray(quant.fake_quant(np.float32([x]), scale))[0]
    assert np.isclose(y, x, rtol=1e-6, atol=1e-7)


def test_fake_quant_error_bound():
    rng = np.random.default_rng(7)
    x = rng.uniform(-1.9, 1.9, size=1024).astype(np.float32)
    scale = 1.0 / 64.0
    err = np.abs(np.asarray(quant.fake_quant(x, scale)) - x)
    assert (err <= scale / 2 + 1e-7).all()


def test_pick_scale_covers_range():
    x = np.float32([-3.7, 0.1, 2.5])
    s = float(quant.pick_scale(x))
    q = np.asarray(quant.quantize(x, s))
    # max-magnitude element maps to the edge of the grid without clipping
    assert abs(q).max() == 127


def test_pick_scale_zero_input():
    s = float(quant.pick_scale(np.zeros(4, np.float32)))
    assert s > 0  # no divide-by-zero downstream
