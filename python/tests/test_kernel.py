"""Kernel-vs-oracle correctness: the CORE signal for Layer 1.

Float comparisons are exact (==) wherever the computation is integer-exact
on the int8 grid (projections, SV); softmax paths use tight allclose.
Hypothesis sweeps shapes/dtypes per the repro mandate.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile import testdata
from compile.kernels import mha_tiled, ref
from compile.topologies import Topology

hypothesis.settings.register_profile(
    "kernels", max_examples=20, deadline=None,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernels")


def mk(seed, *shape):
    return testdata.gen_matrix(seed, shape[0], int(np.prod(shape[1:]))) \
        .reshape(*shape).astype(np.float32)


# --------------------------------------------------------------------- QKV

@pytest.mark.parametrize("sl,dm,dk,ts", [
    (8, 128, 32, 32), (16, 256, 64, 64), (64, 768, 96, 64), (4, 64, 16, 16),
])
def test_qkv_tiled_matches_ref_exactly(sl, dm, dk, ts):
    x = mk(1, sl, dm)
    wq, wk, wv = mk(2, dk, dm), mk(3, dk, dm), mk(4, dk, dm)
    bq, bk, bv = mk(5, 1, dk)[0], mk(6, 1, dk)[0], mk(7, 1, dk)[0]
    q, k, v = mha_tiled.qkv_projection_tiled(x, wq, wk, wv, bq, bk, bv, ts)
    assert np.array_equal(np.asarray(q), np.asarray(ref.qkv_projection(x, wq, bq)))
    assert np.array_equal(np.asarray(k), np.asarray(ref.qkv_projection(x, wk, bk)))
    assert np.array_equal(np.asarray(v), np.asarray(ref.qkv_projection(x, wv, bv)))


def test_qkv_tiled_equals_untiled_reference_tiling():
    """ref.tiled_qkv_projection is itself exactly the direct projection —
    the tiling invariant the paper's Fig. 4 relies on."""
    x, w, b = mk(11, 16, 128), mk(12, 32, 128), mk(13, 1, 32)[0]
    direct = ref.qkv_projection(x, w, b)
    for ts in (16, 32, 64, 128):
        tiled = ref.tiled_qkv_projection(x, w, b, ts)
        assert np.array_equal(np.asarray(tiled), np.asarray(direct))


def test_qkv_tiled_rejects_non_divisible_tile():
    x, w = mk(1, 8, 100), mk(2, 16, 100)
    b = mk(3, 1, 16)[0]
    with pytest.raises(ValueError, match="tile size"):
        mha_tiled.qkv_projection_tiled(x, w, w, w, b, b, b, 48)


@hypothesis.given(
    sl=st.sampled_from([4, 8, 16]),
    n_tiles=st.integers(1, 4),
    ts=st.sampled_from([8, 16, 32]),
    dk=st.sampled_from([8, 16, 32]),
    seed=st.integers(1, 1000))
def test_qkv_tiled_property(sl, n_tiles, ts, dk, seed):
    dm = n_tiles * ts
    x, w, b = mk(seed, sl, dm), mk(seed + 1, dk, dm), mk(seed + 2, 1, dk)[0]
    q, _, _ = mha_tiled.qkv_projection_tiled(x, w, w, w, b, b, b, ts)
    assert np.array_equal(np.asarray(q), np.asarray(ref.qkv_projection(x, w, b)))


# ------------------------------------------------------------------ scores

@pytest.mark.parametrize("sl,dk", [(8, 16), (16, 64), (64, 96)])
def test_attention_scores_match_ref(sl, dk):
    q, k = mk(21, sl, dk), mk(22, sl, dk)
    scale = ref.scale_factor(dk * 8, 8)
    s = mha_tiled.attention_scores(q, k, scale)
    want = ref.softmax(jnp.dot(q, k.T) * scale)
    np.testing.assert_allclose(np.asarray(s), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_scores_rows_sum_to_one():
    q, k = mk(31, 16, 32), mk(32, 16, 32)
    s = np.asarray(mha_tiled.attention_scores(q, k, 0.125))
    np.testing.assert_allclose(s.sum(axis=-1), np.ones(16), rtol=1e-6)
    assert (s >= 0).all()


# ---------------------------------------------------------------------- SV

@pytest.mark.parametrize("sl,dk", [(8, 16), (64, 96)])
def test_weighted_values_match_ref(sl, dk):
    s, v = mk(41, sl, sl), mk(42, sl, dk)
    out = mha_tiled.weighted_values(s, v)
    assert np.array_equal(np.asarray(out), np.asarray(jnp.dot(s, v)))


# ------------------------------------------------------------------- fused

@pytest.mark.parametrize("sl,dk", [(8, 16), (16, 64), (64, 96)])
def test_fused_head_matches_composition(sl, dk):
    q, k, v = mk(51, sl, dk), mk(52, sl, dk), mk(53, sl, dk)
    scale = 1.0 / np.sqrt(dk)
    fused = mha_tiled.fused_attention_head(q, k, v, scale)
    composed = mha_tiled.weighted_values(
        mha_tiled.attention_scores(q, k, scale), v)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(composed),
                               rtol=1e-6, atol=1e-7)
    want = ref.attention_head(q, k, v, scale)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


# --------------------------------------------------------------------- MHA

@pytest.mark.parametrize("sl,dm,h,ts", [
    (8, 128, 4, 32), (16, 256, 8, 64), (16, 256, 2, 32), (32, 768, 8, 64),
])
def test_mha_tiled_matches_ref(sl, dm, h, ts):
    topo = Topology(sl, dm, h, ts)
    args = testdata.gen_inputs(topo)
    scale = ref.scale_factor(dm, h)
    got = mha_tiled.mha_tiled(*args, ts, scale)
    want = ref.mha(*args)
    assert got.shape == (sl, dm)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_mha_tiled_unfused_path_matches():
    topo = Topology(8, 128, 4, 32)
    args = testdata.gen_inputs(topo)
    scale = ref.scale_factor(128, 4)
    fused = mha_tiled.mha_tiled(*args, 32, scale, fused=True)
    unfused = mha_tiled.mha_tiled(*args, 32, scale, fused=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-6, atol=1e-7)


@hypothesis.given(
    sl=st.sampled_from([4, 8, 16]),
    h=st.sampled_from([1, 2, 4]),
    dk=st.sampled_from([8, 16]),
    ts=st.sampled_from([16, 32]),
    seed=st.integers(1, 500))
def test_mha_tiled_property_sweep(sl, h, dk, ts, seed):
    dm = h * dk
    hypothesis.assume(dm % ts == 0)
    x = mk(seed, sl, dm)
    wq, wk, wv = (mk(seed + i, h * dk, dm).reshape(h, dk, dm)
                  for i in (1, 2, 3))
    bq, bk, bv = (mk(seed + i, h, dk) for i in (4, 5, 6))
    scale = ref.scale_factor(dm, h)
    got = mha_tiled.mha_tiled(x, wq, wk, wv, bq, bk, bv, ts, scale)
    want = ref.mha(x, wq, wk, wv, bq, bk, bv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_head_concat_order():
    """Heads must concatenate along features in head order (Fig. 2)."""
    topo = Topology(4, 32, 2, 16)
    x, wq, wk, wv, bq, bk, bv = testdata.gen_inputs(topo)
    scale = ref.scale_factor(32, 2)
    full = np.asarray(mha_tiled.mha_tiled(x, wq, wk, wv, bq, bk, bv, 16, scale))
    for i in range(2):
        q = ref.qkv_projection(x, wq[i], bq[i])
        k = ref.qkv_projection(x, wk[i], bk[i])
        v = ref.qkv_projection(x, wv[i], bv[i])
        head = np.asarray(ref.attention_head(q, k, v, scale))
        np.testing.assert_allclose(full[:, i * 16:(i + 1) * 16], head,
                                   rtol=1e-6, atol=1e-7)


# ------------------------------------------------------------------ causal

@pytest.mark.parametrize("sl,dk", [(8, 16), (16, 64)])
def test_causal_fused_head_matches_ref(sl, dk):
    q, k, v = mk(61, sl, dk), mk(62, sl, dk), mk(63, sl, dk)
    scale = 1.0 / np.sqrt(dk)
    got = mha_tiled.fused_attention_head(q, k, v, scale, causal=True)
    want = ref.attention_head(q, k, v, scale, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-7)


def test_causal_first_row_sees_only_itself():
    """Row 0 of masked attention must equal V's row 0 exactly."""
    q, k, v = mk(71, 8, 16), mk(72, 8, 16), mk(73, 8, 16)
    out = np.asarray(ref.attention_head(q, k, v, 0.25, causal=True))
    np.testing.assert_allclose(out[0], np.asarray(v)[0], rtol=1e-6)


def test_causal_mha_differs_from_dense():
    topo = Topology(8, 128, 4, 32)
    args = testdata.gen_inputs(topo)
    scale = ref.scale_factor(128, 4)
    dense = np.asarray(mha_tiled.mha_tiled(*args, 32, scale))
    masked = np.asarray(mha_tiled.mha_tiled(*args, 32, scale, causal=True))
    assert not np.array_equal(dense, masked)
    # last row attends to everything in both cases -> identical
    np.testing.assert_allclose(dense[-1], masked[-1], rtol=1e-5, atol=1e-6)


def test_causal_prefix_invariance():
    """Masked attention on a prefix equals the prefix of masked attention
    on the full sequence — the property decoding relies on."""
    topo = Topology(12, 64, 2, 16)
    x, wq, wk, wv, bq, bk, bv = testdata.gen_inputs(topo)
    scale = ref.scale_factor(64, 2)
    full = np.asarray(ref.mha(x, wq, wk, wv, bq, bk, bv, causal=True))
    pre = np.asarray(ref.mha(x[:5], wq, wk, wv, bq, bk, bv, causal=True))
    np.testing.assert_allclose(full[:5], pre, rtol=1e-5, atol=1e-6)
