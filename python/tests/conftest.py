"""Shared fixtures: make `compile` importable and provide small topologies."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xFA0005)
