"""Layer-2 model tests: shapes, quantized path, encoder extension."""

import numpy as np
import pytest

from compile import model, testdata
from compile.kernels import ref
from compile.topologies import Topology


@pytest.mark.parametrize("topo", [
    Topology(8, 128, 4, 32), Topology(16, 256, 8, 64),
])
def test_mha_forward_shape(topo):
    args = testdata.gen_inputs(topo)
    out = model.mha_forward(*args, tile_size=topo.tile_size)
    assert out.shape == (topo.seq_len, topo.d_model)


def test_quant_path_is_exact_on_grid_inputs():
    """testdata inputs already live on the int8 grid, so the quantized and
    float paths must agree bit-for-bit (the datapath-emulation premise)."""
    topo = Topology(16, 256, 4, 64)
    args = testdata.gen_inputs(topo)
    f = np.asarray(model.mha_forward(*args, tile_size=64))
    q = np.asarray(model.mha_forward_quant(*args, tile_size=64))
    assert np.array_equal(f, q)


def test_quant_path_quantizes_off_grid_inputs():
    topo = Topology(8, 128, 4, 32)
    args = list(testdata.gen_inputs(topo))
    args[0] = args[0] + 0.003  # push x off the grid
    f = np.asarray(model.mha_forward(*args, tile_size=32))
    q = np.asarray(model.mha_forward_quant(*args, tile_size=32))
    assert not np.array_equal(f, q)
    # but the quantization error stays bounded at int8 scale
    assert np.max(np.abs(f - q)) < 0.15


def test_scale_mode_d_model_differs():
    topo = Topology(8, 128, 4, 32)
    args = testdata.gen_inputs(topo)
    a = np.asarray(model.mha_forward(*args, tile_size=32,
                                     scale_mode="sqrt_dk"))
    b = np.asarray(model.mha_forward(*args, tile_size=32,
                                     scale_mode="d_model"))
    assert not np.array_equal(a, b)


def _encoder_params(topo, d_ff=None):
    d_ff = d_ff or 2 * topo.d_model
    dm, h, dk = topo.d_model, topo.heads, topo.d_k
    g = lambda s, *shape: testdata.gen_matrix(
        s, shape[0], int(np.prod(shape[1:]))).reshape(*shape)
    return {
        "wq": g(2, h * dk, dm).reshape(h, dk, dm),
        "wk": g(3, h * dk, dm).reshape(h, dk, dm),
        "wv": g(4, h * dk, dm).reshape(h, dk, dm),
        "bq": g(5, h, dk), "bk": g(6, h, dk), "bv": g(7, h, dk),
        "ln1_g": np.ones(dm, np.float32), "ln1_b": np.zeros(dm, np.float32),
        "w1": g(8, dm, d_ff), "b1": g(9, 1, d_ff)[0],
        "w2": g(10, d_ff, dm), "b2": g(11, 1, dm)[0],
        "ln2_g": np.ones(dm, np.float32), "ln2_b": np.zeros(dm, np.float32),
    }


def test_encoder_forward_matches_ref():
    topo = Topology(8, 128, 4, 32)
    params = _encoder_params(topo)
    x = testdata.gen_matrix(1, topo.seq_len, topo.d_model)
    got = np.asarray(model.encoder_forward(x, params, tile_size=32))
    want = np.asarray(ref.encoder_block(x, params))
    assert got.shape == (topo.seq_len, topo.d_model)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_encoder_layernorm_statistics():
    topo = Topology(8, 128, 4, 32)
    params = _encoder_params(topo)
    x = testdata.gen_matrix(1, topo.seq_len, topo.d_model)
    out = np.asarray(model.encoder_forward(x, params, tile_size=32))
    # final LN with unit gamma / zero beta -> rows ~N(0,1)
    np.testing.assert_allclose(out.mean(-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(-1), 1.0, atol=1e-2)


def test_encoder_params_shape_registry():
    shapes = model.encoder_params_shape(8, 128, 4)
    assert shapes["wq"].shape == (4, 32, 128)
    assert shapes["w1"].shape == (128, 512)
    p = _encoder_params(Topology(8, 128, 4, 32), d_ff=512)
    for k, s in shapes.items():
        assert tuple(p[k].shape) == tuple(s.shape), k
