//! Design-space exploration (Section VI's analysis, automated).
//!
//! Sweeps tile size × head count × device, printing for each candidate
//! build its resource estimate, feasibility, and modeled latency on the
//! BERT-variant workload — reproducing the paper's findings that
//! (a) TS=64 with 8 heads is the best feasible U55C point, (b) U200 caps
//! at 6 heads, and (c) smaller tiles trade resources for latency.
//!
//!     cargo run --release --example design_space

use famous::analytical::LatencyModel;
use famous::config::Topology;
use famous::fpga::{Device, ResourceModel};
use famous::report::{fmt_f, Table};

fn main() {
    let rm = ResourceModel::default();
    let lm = LatencyModel::default();
    let workload = (64usize, 768usize); // SL, d_model (BERT variant)

    for dev in [Device::alveo_u55c(), Device::alveo_u200()] {
        let mut t = Table::new(
            format!("Design space on {} (SL={}, d_model={})", dev.name, workload.0, workload.1),
            &["TS", "heads", "DSP", "BRAM18k", "LUT", "LUT%", "fits", "latency ms", "GOPS"],
        );
        let mut best: Option<(f64, usize, usize)> = None;
        for ts in [16usize, 32, 64, 128] {
            if workload.1 % ts != 0 {
                continue;
            }
            for h in [2usize, 4, 6, 8, 12] {
                if workload.1 % h != 0 {
                    continue;
                }
                let topo = Topology::new(workload.0, workload.1, h, ts);
                let est = rm.estimate(&topo);
                let fits = est.fits(&dev);
                let ms = lm.predict(&topo).total_ms();
                let gops = famous::metrics::OpCount::paper_convention(&topo) / (ms * 1e-3);
                if fits {
                    match best {
                        Some((b, _, _)) if b <= ms => {}
                        _ => best = Some((ms, ts, h)),
                    }
                }
                t.row(vec![
                    ts.to_string(),
                    h.to_string(),
                    est.dsp.to_string(),
                    est.bram18k.to_string(),
                    est.lut.to_string(),
                    format!("{:.0}%", est.utilization(&dev).lut_pct),
                    if fits { "yes".into() } else { "NO".into() },
                    fmt_f(ms),
                    fmt_f(gops),
                ]);
            }
        }
        print!("{}", t.render());
        if let Some((ms, ts, h)) = best {
            println!(
                "best feasible point on {}: TS={ts}, h={h} at {:.3} ms",
                dev.name, ms
            );
        }
        let max_h = rm.max_heads(&dev, workload.1, workload.0, 64);
        println!("max parallel heads at TS=64: {max_h} (paper: {})\n", match dev.name.as_str() {
            "alveo_u55c" => 8,
            _ => 6,
        });
    }

    // The paper's headline finding should fall out of the sweep:
    let u55c_best = ResourceModel::default().max_heads(&Device::alveo_u55c(), 768, 64, 64);
    assert_eq!(u55c_best, 8, "U55C should cap at 8 heads");
    let u200_best = ResourceModel::default().max_heads(&Device::alveo_u200(), 768, 64, 64);
    assert_eq!(u200_best, 6, "U200 should cap at 6 heads");
    println!("design_space OK (paper's head limits reproduced)");
}
