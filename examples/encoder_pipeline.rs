//! Encoder extension (the paper's stated future work, Section VIII):
//! a full transformer encoder stack where each layer's MHA runs on the
//! modeled accelerator and the position-wise FFN + residual + LayerNorm
//! run on the host — the split the paper's Fig. 5 system implies.
//!
//! Demonstrates multi-layer composition through the coordinator and
//! checks the numerics against a pure-host reference implementation.
//!
//!     cargo run --release --example encoder_pipeline

use famous::accel::FamousAccelerator;
use famous::config::Topology;
use famous::sim::SimConfig;
use famous::testdata::{gen_matrix, MhaInputs};

const LAYERS: usize = 4;

/// Host-side layer norm (unit gamma, zero beta).
fn layer_norm(x: &mut [f32], rows: usize, cols: usize) {
    for r in 0..rows {
        let row = &mut x[r * cols..(r + 1) * cols];
        let mean = row.iter().sum::<f32>() / cols as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
        let inv = 1.0 / (var + 1e-5).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * inv;
        }
    }
}

/// Host-side FFN: ReLU(x W1 + b1) W2 + b2 with d_ff = 2·d_model.
fn ffn(x: &[f32], rows: usize, dm: usize, w1: &[f32], w2: &[f32]) -> Vec<f32> {
    let dff = 2 * dm;
    let mut mid = vec![0f32; rows * dff];
    for i in 0..rows {
        for j in 0..dff {
            let mut acc = 0f32;
            for l in 0..dm {
                acc += x[i * dm + l] * w1[l * dff + j];
            }
            mid[i * dff + j] = acc.max(0.0);
        }
    }
    let mut out = vec![0f32; rows * dm];
    for i in 0..rows {
        for j in 0..dm {
            let mut acc = 0f32;
            for l in 0..dff {
                acc += mid[i * dff + l] * w2[l * dm + j];
            }
            out[i * dm + j] = acc;
        }
    }
    out
}

fn main() -> anyhow::Result<()> {
    let topo = Topology::new(64, 256, 8, 64); // small encoder, 4 layers
    let (sl, dm) = (topo.seq_len, topo.d_model);
    println!("== encoder pipeline: {LAYERS} layers of {topo} ==");

    // MHA on the accelerator (PJRT artifacts), FFN/LN on the host.
    let mut accel = FamousAccelerator::with_pjrt(SimConfig::u55c(), "artifacts")?;

    // Per-layer parameters from the deterministic stream. FFN weights are
    // scaled down to keep activations in a stable range.
    let mha_params: Vec<MhaInputs> = (0..LAYERS).map(|_| MhaInputs::generate(&topo)).collect();
    let ffn_w: Vec<(Vec<f32>, Vec<f32>)> = (0..LAYERS)
        .map(|l| {
            let s = 1.0 / (dm as f32).sqrt();
            let w1: Vec<f32> =
                gen_matrix(100 + l as u64, dm, 2 * dm).iter().map(|v| v * s).collect();
            let w2: Vec<f32> =
                gen_matrix(200 + l as u64, 2 * dm, dm).iter().map(|v| v * s).collect();
            (w1, w2)
        })
        .collect();

    let mut x = gen_matrix(999, sl, dm);
    let mut total_fabric_ms = 0.0;
    for layer in 0..LAYERS {
        // Accelerator step: MHA over the current activations.  The x
        // stream is re-quantized at the accelerator boundary, exactly as
        // the hardware ingests activations into the int8 datapath.
        let mut inp = mha_params[layer].clone();
        inp.x = x.clone();
        let report = accel.run(&topo, &inp)?;
        total_fabric_ms += report.latency_ms;
        // Host: residual + LN.
        for (xi, ai) in x.iter_mut().zip(&report.output) {
            *xi += ai;
        }
        layer_norm(&mut x, sl, dm);
        // Host: FFN + residual + LN.
        let f = ffn(&x, sl, dm, &ffn_w[layer].0, &ffn_w[layer].1);
        for (xi, fi) in x.iter_mut().zip(&f) {
            *xi += fi;
        }
        layer_norm(&mut x, sl, dm);
        println!(
            "layer {layer}: fabric {:.3} ms, activation rms {:.3}",
            report.latency_ms,
            (x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32).sqrt()
        );
    }
    println!("total fabric time for {LAYERS} layers: {total_fabric_ms:.3} ms");

    // Sanity: LN keeps activations normalized and finite.
    assert!(x.iter().all(|v| v.is_finite()));
    let rms = (x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32).sqrt();
    assert!((rms - 1.0).abs() < 0.05, "post-LN rms should be ~1, got {rms}");
    println!("encoder_pipeline OK");
    Ok(())
}
