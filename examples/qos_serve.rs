//! QoS serving driver: deadline/priority traffic on a 4-device fleet.
//!
//! The scenario the ROADMAP's multi-tenant north star implies: an
//! open-loop, bursty arrival stream (two-state MMPP on the virtual
//! clock) with three priority classes — latency-critical `High` on a
//! tight deadline budget, `Normal` interactive traffic, sheddable
//! `Low` background work — replayed through the same fleet twice:
//!
//! * the PR-1 **FIFO/affinity** policy, which pins every topology to
//!   its hot device and silently queues late work;
//! * the QoS **EDF + slack** policy (`ClusterConfig::qos()`):
//!   EDF-within-window batching per device, slack-aware routing that
//!   spreads deadline-infeasible load across the fleet, and explicit
//!   shedding of provably-late `Low` requests.
//!
//! Both runs print the fleet report with the per-priority SLO block
//! (p50/p99 sojourn, miss rate, shed counts); the driver then verifies
//! a served sample bit-identical against a serial single-accelerator
//! run and asserts the EDF side won.
//!
//!     cargo run --release --example qos_serve

use famous::accel::FamousAccelerator;
use famous::cluster::loadgen::{mean_service_ms, rate_for_utilization};
use famous::cluster::telemetry::render_top;
use famous::cluster::{
    Arrival, Cluster, ClusterConfig, DeviceSpec, FleetStats, LoadGen, LoadGenConfig, QosOutcome,
    QosPolicy, TelemetryConfig, WorkloadProfile,
};
use famous::config::Topology;
use famous::coordinator::{BatchPolicy, Priority, SchedulerConfig};
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;

const N_REQUESTS: usize = 160;
const SEED: u64 = 0x9035_7e57;

fn mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(64, 768, 8, 64), 3.0),
        (Topology::new(32, 768, 8, 64), 2.0),
        (Topology::new(64, 512, 8, 64), 1.0),
    ]
}

fn replay(
    arrivals: &[Arrival],
    policy: QosPolicy,
    operator_report: bool,
) -> anyhow::Result<(FleetStats, Vec<(Topology, Vec<f32>)>)> {
    let m = mix();
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    let base_ms = mean_service_ms(&devices, &m);
    let scheduler = SchedulerConfig {
        max_batch: 8,
        policy: match policy {
            QosPolicy::SlackEdf => BatchPolicy::EdfWithinWindow,
            QosPolicy::Affinity => BatchPolicy::GroupByTopology,
        },
        fairness_window: 16,
    };
    let mut workload = WorkloadProfile::default();
    for (t, share) in &m {
        workload.push(t.clone(), *share);
    }
    let cluster = Cluster::start(
        devices,
        &workload,
        ClusterConfig {
            scheduler,
            qos: policy,
            // Windows scaled to the mean service time so this short
            // trace seals a ring worth looking at.
            telemetry: TelemetryConfig {
                window_ms: 12.0 * base_ms,
                ..TelemetryConfig::default()
            },
            ..ClusterConfig::default()
        },
    )?;
    let names = cluster.device_names();
    let h = cluster.handle();
    let mut served = Vec::new();
    for (i, a) in arrivals.iter().enumerate() {
        if let QosOutcome::Served(resp) = h.call_qos(a.materialize(i as u64))? {
            served.push((resp.topology.clone(), resp.output));
        }
        // The periodic operator report: the dashboard a `famous top`
        // operator would watch, rendered from whatever the watermark
        // has sealed so far (no forced flush — late windows stay open).
        if operator_report && (i + 1) % 40 == 0 {
            let snap = cluster.telemetry();
            println!("-- operator report after {} arrivals --", i + 1);
            print!("{}", render_top(&snap.frames, &names, cluster.control_log()));
        }
    }
    if operator_report {
        cluster.seal_telemetry();
        let snap = cluster.telemetry();
        println!("-- final telemetry ({} sealed frames) --", snap.frames.len());
        print!("{}", render_top(&snap.frames, &names, cluster.control_log()));
        println!("frame export sample (JSONL):");
        for line in snap.to_jsonl().lines().take(2) {
            println!("  {line}");
        }
    }
    Ok((cluster.shutdown(), served))
}

fn main() -> anyhow::Result<()> {
    let m = mix();
    let devices: Vec<DeviceSpec> = (0..4).map(DeviceSpec::u55c).collect();
    let base_ms = mean_service_ms(&devices, &m);
    let rate_hz = rate_for_utilization(&devices, &m, 0.9);
    println!("== FAMOUS QoS serving driver ==");
    println!(
        "fleet: 4x U55C; {N_REQUESTS} bursty requests at {rate_hz:.0} req/s offered \
         (mean service {base_ms:.3} ms)"
    );
    // The shared bursty preset: MMPP averaging 0.9 of fleet capacity,
    // High/Normal/Low classes on 4x/8x/12x mean-service budgets.
    let arrivals = LoadGen::new(LoadGenConfig::bursty_preset(&devices, m.clone(), 0.9, SEED))
        .generate_n(N_REQUESTS);
    println!(
        "trace: {:.1} virtual ms, classes high/normal/low = {}/{}/{}",
        arrivals.last().map(|a| a.arrival_ms).unwrap_or(0.0),
        arrivals.iter().filter(|a| a.priority == Priority::High).count(),
        arrivals.iter().filter(|a| a.priority == Priority::Normal).count(),
        arrivals.iter().filter(|a| a.priority == Priority::Low).count(),
    );

    println!("\n-- FIFO/affinity (PR-1 policy) --");
    let (fifo, _) = replay(&arrivals, QosPolicy::Affinity, false)?;
    print!("{}", fifo.render());

    println!("-- EDF + slack (ClusterConfig::qos) --");
    let (edf, served) = replay(&arrivals, QosPolicy::SlackEdf, true)?;
    print!("{}", edf.render());

    // Verify a served sample bit-identical to a serial run (operands
    // are deterministic per topology: one reference per shape).
    let mut accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let mut verified = 0;
    for (topo, _) in &m {
        let want = accel.run(topo, &MhaInputs::generate(topo))?.output;
        for (t, out) in served.iter().filter(|(t, _)| t == topo) {
            assert_eq!(out, &want, "cluster output diverged for {t}");
            verified += 1;
        }
    }
    println!("verified {verified}/{} served outputs bit-identical to serial runs", served.len());

    let v = |f: &FleetStats| {
        Priority::ALL.iter().map(|&p| f.totals.slo.violations(p)).sum::<u64>()
    };
    assert!(
        v(&edf) < v(&fifo),
        "EDF+slack violations {} !< FIFO/affinity {}",
        v(&edf),
        v(&fifo)
    );
    println!(
        "SLO violations at equal offered load: edf+slack {} < fifo/affinity {} — qos_serve OK",
        v(&edf),
        v(&fifo)
    );
    Ok(())
}
