//! End-to-end driver (DESIGN.md §5 "Headline"): the full three-layer
//! system serving a realistic batched request stream.
//!
//! * Layer 1/2 — the jax/Pallas MHA kernels, AOT'd to `artifacts/` and
//!   executed through PJRT on the request path (python never runs here).
//! * Layer 3 — the rust coordinator: threaded server, bounded ingress,
//!   topology-grouping batcher, runtime reprogramming of the modeled
//!   accelerator between batches.
//!
//! The workload models an inference service hosting three transformer
//! apps with different topologies (the paper's flexibility scenario —
//! "different applications require different [configurations]" — served
//! WITHOUT re-synthesis).  Requests arrive from concurrent clients in a
//! bursty pattern; we report wall-clock throughput, modeled fabric
//! latency percentiles, reconfiguration counts, and verify every output
//! against the independent int8-datapath implementation.
//!
//!     make artifacts && cargo run --release --example e2e_serve

use famous::accel::FamousAccelerator;
use famous::config::Topology;
use famous::coordinator::{
    BatchPolicy, Coordinator, Request, SchedulerConfig, Server, ServerConfig,
};
use famous::metrics::LatencyStats;
use famous::runtime::{Backend, SimBackend};
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;
use std::sync::{Arc, Mutex};
use std::time::Instant;

const N_CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 12;

fn main() -> anyhow::Result<()> {
    // Three "applications" sharing one synthesized U55C build.
    let apps = [
        ("bert-variant", Topology::new(64, 768, 8, 64)),
        ("short-seq-clf", Topology::new(32, 768, 8, 64)),
        ("small-embed", Topology::new(64, 512, 8, 64)),
    ];
    println!("== FAMOUS end-to-end serving driver ==");
    println!(
        "build: U55C TS=64 (synth maxima SL=128, d_model=768, h=8); {} clients x {} reqs",
        N_CLIENTS, REQS_PER_CLIENT
    );

    let srv = Server::start(
        || {
            let accel = FamousAccelerator::with_pjrt(SimConfig::u55c(), "artifacts")
                .expect("run `make artifacts` first");
            Coordinator::new(
                accel,
                SchedulerConfig {
                    max_batch: 16,
                    policy: BatchPolicy::GroupByTopology,
                    fairness_window: 64,
                },
            )
        },
        ServerConfig { queue_capacity: 128, ingest_burst: 32 },
    );

    let wall_stats = Arc::new(Mutex::new(LatencyStats::default()));
    let outputs = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for client in 0..N_CLIENTS {
        let h = srv.handle();
        let apps = apps.clone();
        let wall_stats = Arc::clone(&wall_stats);
        let outputs = Arc::clone(&outputs);
        joins.push(std::thread::spawn(move || {
            for k in 0..REQS_PER_CLIENT {
                // Bursty arrival: client favors one app, occasionally hits
                // the others (forces topology switches).
                let (app, topo) = &apps[if k % 4 == 3 { (client + k) % 3 } else { client % 3 }];
                let id = (client * REQS_PER_CLIENT + k) as u64;
                let inputs = MhaInputs::generate(topo);
                let treq = Instant::now();
                let resp = h
                    .call_blocking(Request::new(id, topo.clone(), inputs))
                    .expect("request served");
                wall_stats.lock().unwrap().record(treq.elapsed().as_secs_f64() * 1e3);
                outputs.lock().unwrap().push((resp.topology.clone(), resp.output, *app));
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let stats = srv.shutdown();

    let total = N_CLIENTS * REQS_PER_CLIENT;
    println!("-- serving results --");
    println!("served              : {}/{} requests", stats.served, total);
    println!("wall time           : {wall_s:.2} s  ({:.1} req/s)", total as f64 / wall_s);
    println!("batches             : {}", stats.batches);
    println!(
        "reconfigurations    : {} (vs {} batches — batching amortizes switches)",
        stats.reconfigurations, stats.batches
    );
    println!(
        "fabric latency      : p50 {:.3} ms  p99 {:.3} ms  mean {:.3} ms",
        stats.fabric_latency.percentile(50.0),
        stats.fabric_latency.percentile(99.0),
        stats.fabric_latency.mean()
    );
    let ws = wall_stats.lock().unwrap();
    println!(
        "client E2E latency  : p50 {:.2} ms  p99 {:.2} ms (includes queueing)",
        ws.percentile(50.0),
        ws.percentile(99.0)
    );
    assert_eq!(stats.served as usize, total);

    // Verify every served output against the independent rust datapath.
    println!("-- verification (PJRT vs int8 simulator datapath) --");
    let mut simb = SimBackend::new(SimConfig::u55c());
    let mut worst = 0f32;
    let outs = outputs.lock().unwrap();
    for (topo, out, _app) in outs.iter() {
        let want = simb.run_mha(topo, &MhaInputs::generate(topo))?;
        let err = out.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        worst = worst.max(err);
    }
    println!("verified {} outputs, worst |diff| = {worst:.2e}", outs.len());
    assert!(worst < 1e-4);
    println!("e2e_serve OK");
    Ok(())
}
