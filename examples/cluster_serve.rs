//! Cluster serving driver: a heterogeneous FPGA fleet under mixed
//! traffic (the ROADMAP's scale-*out* story).
//!
//! The fleet is two U55Cs plus two U200s — four different resource
//! envelopes behind one ingress.  Traffic mixes the paper's flexibility
//! scenario across model sizes and sequence lengths:
//!
//! * BERT-base shapes at short (SL 32) and long (SL 64/128) sequence
//!   lengths — the length-adaptive routing lever of Peng et al.;
//! * an h=6 shape the U200s can serve (their LUT budget caps heads at
//!   6, Section VI);
//! * BERT-large (d_model 1024, 16 heads): no single build admits it, so
//!   the router head-shards it across two devices and reassembles the
//!   output on the host (FTRANS-style cross-FPGA partitioning).
//!
//! Every response is verified bit-identical against a local
//! single-device run of the same request, then the fleet report is
//! printed: per-device utilization/occupancy, cluster GOPS, latency
//! percentiles, reconfiguration counts.
//!
//!     cargo run --release --example cluster_serve

use famous::accel::FamousAccelerator;
use famous::cluster::{Cluster, ClusterConfig, DeviceSpec, ShardPlan, WorkloadProfile};
use famous::config::Topology;
use famous::coordinator::Request;
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;
use std::sync::{Arc, Mutex};
use std::time::Instant;

const N_CLIENTS: usize = 6;
const REQS_PER_CLIENT: usize = 8;

fn main() -> anyhow::Result<()> {
    let fleet = vec![
        DeviceSpec::u55c(0),
        DeviceSpec::u55c(1),
        DeviceSpec::u200(2),
        DeviceSpec::u200(3),
    ];
    // (topology, traffic share): short-SL classification traffic
    // dominates, long-SL and BERT-large are the heavy tail.
    let mut workload = WorkloadProfile::default();
    let apps: Vec<(&str, Topology, f64)> = vec![
        ("bert-base-sl64", Topology::new(64, 768, 8, 64), 3.0),
        ("bert-base-sl32", Topology::new(32, 768, 8, 64), 4.0),
        ("bert-base-sl128", Topology::new(128, 768, 8, 64), 1.0),
        ("h6-encoder", Topology::new(64, 768, 6, 64), 3.0),
        ("bert-large", Topology::new(64, 1024, 16, 64), 1.0),
    ];
    for (_, t, share) in &apps {
        workload.push(t.clone(), *share);
    }

    println!("== FAMOUS cluster serving driver ==");
    println!(
        "fleet: 2x U55C + 2x U200; {} clients x {} requests over {} apps",
        N_CLIENTS,
        REQS_PER_CLIENT,
        apps.len()
    );
    let cluster = Cluster::start(fleet, &workload, ClusterConfig::default())?;
    for p in &cluster.plan().placements {
        println!(
            "  plan: {} -> devices {:?}{}",
            p.topology,
            p.devices,
            if p.shard.is_some() { " (head-sharded)" } else { "" }
        );
    }

    let outputs = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for client in 0..N_CLIENTS {
        let h = cluster.handle();
        let apps = apps.clone();
        let outputs = Arc::clone(&outputs);
        joins.push(std::thread::spawn(move || {
            for k in 0..REQS_PER_CLIENT {
                // Each client favors one app, with periodic excursions
                // (forces cross-topology traffic on every device).
                let (name, topo, _) =
                    &apps[if k % 4 == 3 { (client + k) % apps.len() } else { client % apps.len() }];
                let id = (client * REQS_PER_CLIENT + k) as u64;
                let inputs = MhaInputs::generate(topo);
                let resp = h
                    .call(Request::new(id, topo.clone(), inputs.clone()))
                    .expect("request served");
                outputs.lock().unwrap().push((*name, topo.clone(), inputs, resp));
            }
        }));
    }
    // Observe the fleet mid-run (no drain): the live-snapshot path an
    // operator dashboard would poll.
    let snap = cluster.fleet_snapshot();
    println!(
        "-- live snapshot -- {} completed, {} device invocations, {} reconfigs, {:.0}% cache hits",
        snap.totals.completed,
        snap.served(),
        snap.reconfigurations(),
        snap.program_cache_hit_rate() * 100.0
    );
    for j in joins {
        j.join().unwrap();
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let fleet_stats = cluster.shutdown();

    let total = N_CLIENTS * REQS_PER_CLIENT;
    println!("-- fleet report --");
    print!("{}", fleet_stats.render());
    println!(
        "wall time {wall_s:.2} s ({:.1} req/s host-side)",
        total as f64 / wall_s
    );
    assert_eq!(fleet_stats.totals.completed as usize, total);

    // Verify every response bit-identical to a single-device run.
    println!("-- verification (cluster vs single-device accelerator) --");
    let mut accel = FamousAccelerator::with_sim_datapath(SimConfig::u55c());
    let outs = outputs.lock().unwrap();
    let mut verified = 0;
    let mut sharded = 0;
    for (_name, topo, inputs, resp) in outs.iter() {
        let want = if resp.sharded {
            sharded += 1;
            let plan = ShardPlan::plan(topo).expect("sharded response implies a plan");
            let (lo, hi) = plan.split_inputs(inputs)?;
            let lo_out = accel.run(&plan.half, &lo)?.output;
            let hi_out = accel.run(&plan.half, &hi)?.output;
            plan.concat_outputs(&lo_out, &hi_out)?
        } else {
            accel.run(topo, inputs)?.output
        };
        assert_eq!(resp.output, want, "cluster output diverged for {topo}");
        verified += 1;
    }
    println!("verified {verified}/{total} outputs bit-identical ({sharded} sharded)");
    println!("cluster_serve OK");
    Ok(())
}
