use famous::benchlib::{bench, black_box};
use famous::fixed::{matmul_i32, matmul_i32_fast, matmul_i32_tiled, FxMatrix};
use famous::rng::XorShift64;
fn rand_mat(seed: u64, rows: usize, cols: usize) -> FxMatrix {
    let mut rng = XorShift64::new(seed);
    FxMatrix { rows, cols, data: (0..rows*cols).map(|_| rng.range_i64(-128,127) as i8).collect() }
}
fn main() {
    let a = rand_mat(1, 64, 768);
    let b = rand_mat(2, 96, 768);
    let macs = (64*768*96) as f64;
    let s = bench(3, 30, || { black_box(matmul_i32(&a,&b)); });
    println!("naive    {:.3} ms  {:.2} Gmac/s", s.min_ms, macs/(s.min_ms*1e-3)/1e9);
    let s = bench(3, 30, || { black_box(matmul_i32_tiled(&a,&b,64)); });
    println!("tiled64  {:.3} ms  {:.2} Gmac/s", s.min_ms, macs/(s.min_ms*1e-3)/1e9);
    let s = bench(3, 30, || { black_box(matmul_i32_fast(&a,&b)); });
    println!("fast     {:.3} ms  {:.2} Gmac/s", s.min_ms, macs/(s.min_ms*1e-3)/1e9);
}
