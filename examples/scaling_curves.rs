//! Scaling curves: latency/GOPS series over each runtime-programmable
//! parameter (the figure-form view of Table I's row families).  Emits
//! aligned tables plus a JSON dump (`scaling_curves.json`) for plotting.
//!
//!     cargo run --release --example scaling_curves

use famous::config::Topology;
use famous::jsonlite::Json;
use famous::metrics::OpCount;
use famous::report::{fmt_f, Table};
use famous::sim::{SimConfig, Simulator};

fn run_ms(topo: &Topology) -> f64 {
    let mut cfg = SimConfig::u55c();
    if topo.tile_size != cfg.build.tile_size {
        cfg.build.tile_size = topo.tile_size;
        cfg.build.max_topology.tile_size = topo.tile_size;
    }
    // Widen admission for the sweep (model extrapolation beyond the
    // paper's synthesized maxima, labeled as such).
    cfg.build.max_topology.seq_len = 1024;
    cfg.build.max_topology.d_model = 4096;
    cfg.build.max_topology.heads = 64;
    Simulator::new(cfg).run_timing(topo).unwrap().latency_ms
}

fn series(
    name: &str,
    pts: Vec<(String, Topology)>,
    out: &mut Vec<(String, Json)>,
) {
    let mut t = Table::new(
        format!("Scaling: {name}"),
        &["x", "latency ms", "GOPS (attn-only)"],
    );
    let mut arr = Vec::new();
    for (x, topo) in &pts {
        let ms = run_ms(topo);
        let gops = OpCount::attention_only(topo).giga() / (ms * 1e-3);
        t.row(vec![x.clone(), fmt_f(ms), fmt_f(gops)]);
        arr.push(Json::obj([
            ("x", Json::from(x.as_str())),
            ("latency_ms", Json::from(ms)),
            ("gops", Json::from(gops)),
        ]));
    }
    print!("{}", t.render());
    out.push((name.to_string(), Json::arr(arr)));
}

fn main() {
    let mut dump = Vec::new();

    // Latency vs sequence length (tests 1, 6-8 extended).
    series(
        "sequence length (d=768, h=8, TS=64)",
        [16, 32, 64, 128, 256, 512]
            .iter()
            .map(|&sl| (sl.to_string(), Topology::new(sl, 768, 8, 64)))
            .collect(),
        &mut dump,
    );
    // Latency vs embedding dimension (tests 1, 4, 5 extended).
    series(
        "embedding dimension (SL=64, h=8, TS=64)",
        [256, 512, 768, 1024, 1536, 2048]
            .iter()
            .map(|&d| (d.to_string(), Topology::new(64, d, 8, 64)))
            .collect(),
        &mut dump,
    );
    // Latency vs runtime head count (tests 1-3 extended).
    series(
        "heads (SL=64, d=768, TS=64)",
        [1, 2, 4, 8, 12, 16]
            .iter()
            .filter(|&&h| 768 % h == 0)
            .map(|&h| (h.to_string(), Topology::new(64, 768, h, 64)))
            .collect(),
        &mut dump,
    );
    // Latency vs tile size (tests 1, 9, 10 extended).
    series(
        "tile size (SL=64, d=768, h=8)",
        [16, 32, 48, 64, 96, 128]
            .iter()
            .filter(|&&ts| 768 % ts == 0)
            .map(|&ts| (ts.to_string(), Topology::new(64, 768, 8, ts)))
            .collect(),
        &mut dump,
    );

    let json = Json::obj(dump.into_iter().collect::<Vec<_>>());
    std::fs::write("scaling_curves.json", json.to_string()).unwrap();
    println!("wrote scaling_curves.json");

    // The monotone shapes Table I implies, asserted over the wider sweep.
    assert!(run_ms(&Topology::new(256, 768, 8, 64)) > run_ms(&Topology::new(128, 768, 8, 64)));
    assert!(run_ms(&Topology::new(64, 2048, 8, 64)) > run_ms(&Topology::new(64, 1024, 8, 64)));
    println!("scaling_curves OK");
}
