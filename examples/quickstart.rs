//! Quickstart: load the AOT artifacts, run one multi-head attention
//! invocation on the modeled U55C accelerator, and verify the output
//! against the python oracle's golden vector.
//!
//!     make artifacts && cargo run --release --example quickstart

use famous::accel::FamousAccelerator;
use famous::config::Topology;
use famous::sim::SimConfig;
use famous::testdata::MhaInputs;

fn main() -> anyhow::Result<()> {
    // The paper's headline configuration: BERT-variant topology on the
    // U55C TS=64 build (Table I test 1).
    let topo = Topology::new(64, 768, 8, 64);
    let mut accel = FamousAccelerator::with_pjrt(SimConfig::u55c(), "artifacts")?;

    // Deterministic int8-grid operands (same stream as the python oracle).
    let inputs = MhaInputs::generate(&topo);
    let report = accel.run(&topo, &inputs)?;

    println!("== FAMOUS quickstart ==");
    println!("topology        : {topo}");
    println!("fabric latency  : {:.3} ms  ({} cycles @ 400 MHz)", report.latency_ms, report.cycles);
    println!("throughput      : {:.0} GOPS (paper convention)", report.gops);
    println!("paper reports   : 0.94 ms / 328 GOPS (Table I test 1)");

    // Cross-check the functional output against the shipped golden vector.
    let rt = famous::runtime::Runtime::load("artifacts")?;
    if let Some(golden) = rt.golden(&topo.name())? {
        let max_err = report
            .output
            .iter()
            .zip(&golden)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        println!("golden check    : max |diff| = {max_err:.2e} (python oracle)");
        assert!(max_err < 1e-5, "output diverged from the oracle");
    }

    // Phase attribution (what the cycle trace is for).
    println!("-- phase breakdown --");
    for name in ["CTRL", "LI", "LB", "LIA", "LWA", "SA", "BA", "S", "SV"] {
        let cycles = report.sim.trace.phase_cycles(name);
        println!(
            "  {name:<4} {cycles:>8} cc  ({:>5.1}%)",
            cycles as f64 / report.cycles as f64 * 100.0
        );
    }
    println!("quickstart OK");
    Ok(())
}
