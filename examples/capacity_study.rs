//! Capacity study: sweep offered load to the SLO knee on the DES.
//!
//! The question the virtual-time simulator exists to answer cheaply
//! (DESIGN.md §16): *how much load can this fleet take before the SLO
//! gives way, and how much headroom does the corrected fused-path
//! timing buy?*  Each sweep point replays a seeded bursty trace through
//! [`FleetSim`] twice — once billing the reference `SL×SL` service
//! model, once billing auto-fused shapes with the corrected per-tile
//! `FusedTiled` trace — and records the deadline-violation rate.  The
//! **knee** is the first offered-load fraction where violations exceed
//! 5% of deadline-bearing traffic.
//!
//! Every point is a fresh simulator on the same seed, so the whole
//! study is deterministic; on the threaded fleet this sweep would cost
//! tens of real minutes, on the DES it is wall-clock seconds.
//!
//!     cargo run --release --example capacity_study

use famous::cluster::{
    ClusterConfig, DesConfig, DesReport, DeviceSpec, FleetSim, LoadGen, LoadGenConfig, QosPolicy,
    WorkloadProfile,
};
use famous::config::Topology;

const SEED: u64 = 0xca9a_c17e;
const N_PER_POINT: usize = 1_500;
const KNEE_VIOLATION_RATE: f64 = 0.05;

/// Long-sequence mix on the streaming build: SL 512 is past the fused
/// threshold (the shapes the ISSUE-9 timing fix actually changes), SL
/// 256 rides along as the short tail.
fn mix() -> Vec<(Topology, f64)> {
    vec![
        (Topology::new(512, 128, 2, 64), 2.0),
        (Topology::new(256, 128, 2, 64), 1.0),
    ]
}

fn sweep_point(rho: f64, fused_service: bool) -> DesReport {
    let m = mix();
    let devices: Vec<DeviceSpec> = (0..2).map(DeviceSpec::u55c_long).collect();
    let mut workload = WorkloadProfile::default();
    for (t, share) in &m {
        workload.push(t.clone(), *share);
    }
    let config = DesConfig {
        cluster: ClusterConfig { qos: QosPolicy::SlackEdf, ..ClusterConfig::default() },
        fused_service,
    };
    let mut sim = FleetSim::new(devices.clone(), &workload, config).expect("fleet boots");
    let mut gen = LoadGen::new(LoadGenConfig::bursty_preset(&devices, m, rho, SEED));
    let report = sim.run(&mut gen, N_PER_POINT);
    assert!(report.conserved(), "conservation failed at rho {rho}: {report:?}");
    report
}

/// First sweep point whose violation rate crosses the knee threshold
/// (`None` when the fleet holds the SLO across the whole sweep).
fn knee(points: &[(f64, DesReport)]) -> Option<f64> {
    points.iter().find(|(_, r)| r.violation_rate() > KNEE_VIOLATION_RATE).map(|(rho, _)| *rho)
}

fn main() {
    let rhos: Vec<f64> = (5..=13).map(|i| i as f64 / 10.0).collect();
    println!("== FAMOUS capacity study (virtual-time DES, DESIGN.md §16) ==");
    println!(
        "fleet: 2x u55c-long; {N_PER_POINT} bursty requests per point, seed {SEED:#x}; \
         knee at violation rate > {:.0}%",
        KNEE_VIOLATION_RATE * 100.0
    );
    println!();
    println!(
        "{:>5}  {:>28}  {:>28}",
        "rho", "reference (SLxSL billing)", "fused (per-tile billing)"
    );
    println!(
        "{:>5}  {:>9} {:>8} {:>9}  {:>9} {:>8} {:>9}",
        "", "viol", "shed", "util", "viol", "shed", "util"
    );

    let mut reference = Vec::new();
    let mut fused = Vec::new();
    let mut wall_ms = 0.0;
    for &rho in &rhos {
        let r = sweep_point(rho, false);
        let f = sweep_point(rho, true);
        wall_ms += r.wall_ms + f.wall_ms;
        let util = |rep: &DesReport| {
            let n = rep.device_busy_ms.len();
            (0..n).map(|i| rep.utilization(i)).sum::<f64>() / n as f64
        };
        let shed = |rep: &DesReport| rep.shed;
        println!(
            "{:>5.2}  {:>8.2}% {:>8} {:>8.0}%  {:>8.2}% {:>8} {:>8.0}%",
            rho,
            r.violation_rate() * 100.0,
            shed(&r),
            util(&r) * 100.0,
            f.violation_rate() * 100.0,
            shed(&f),
            util(&f) * 100.0,
        );
        reference.push((rho, r));
        fused.push((rho, f));
    }

    let knee_ref = knee(&reference);
    let knee_fused = knee(&fused);
    let label = |k: Option<f64>| match k {
        Some(rho) => format!("rho {rho:.2}"),
        None => format!("beyond rho {:.2}", rhos.last().unwrap()),
    };
    println!();
    println!("knee (reference billing): {}", label(knee_ref));
    println!("knee (fused billing):     {}", label(knee_fused));
    println!("sweep simulated in {:.1} ms wall across {} points", wall_ms, 2 * rhos.len());

    // The corrected fused trace is strictly cheaper at SL >= 256, so
    // fused billing can only hold the SLO at least as far up the load
    // axis — the headroom the ISSUE-9 fix recovered.
    let total = |pts: &[(f64, DesReport)]| -> u64 {
        pts.iter().map(|(_, r)| r.totals.slo.total_missed() + r.shed).sum()
    };
    assert!(
        total(&fused) <= total(&reference),
        "fused billing violated more than reference across the sweep: {} > {}",
        total(&fused),
        total(&reference)
    );
    if let (Some(kr), Some(kf)) = (knee_ref, knee_fused) {
        assert!(kf >= kr, "fused knee {kf} moved below reference knee {kr}");
    }
    println!("capacity_study OK: fused billing holds the SLO at least as far as reference");
}
