#!/usr/bin/env python3
"""Bench-regression gate for the execute-path bench (CI).

Compares the BENCH_exec.json just produced by `cargo bench --bench exec`
against the artifact uploaded by the previous successful CI run, and
fails when any wall-time series regressed by more than --max-regress
(default 20%).  Series are matched by their shape key (seq_len, d_model,
heads, lanes).

Coverage is asymmetric on purpose:

* A series (or whole section) present in the *baseline* but missing
  from the new run FAILS the gate with an explicit message — a silently
  dropped sweep point would otherwise make the gate pass vacuously
  while coverage shrinks.
* A series or section that is new in the *current* run (e.g. the `des`
  series against a pre-DES baseline) passes with a notice — there is
  nothing to compare against yet, and next run it becomes the baseline.

The previous artifact is optional by design: on the first run after the
gate lands (or when artifact retention expired) there is nothing to
compare against, and the gate passes with a notice instead of failing —
a missing baseline is not a regression.

Usage: bench_regression.py PREVIOUS CURRENT [--max-regress 0.20]
"""

import argparse
import json
import sys

# section -> wall-time fields gated within it.  Non-time fields
# (speedups, workspace bytes, bit_identical) are asserted by the bench
# itself; this gate only watches absolute wall time drift.
WALL_FIELDS = {
    "results": ("serial_alloc_ms", "serial_warm_ms", "head_parallel_ms"),
    "long_sl": ("reference_ms", "fused_ms"),
    "kernel_tiers": ("scalar_ms", "simd_ms", "simd_int8_ms"),
    "integrity": ("verify_off_ms", "verify_on_ms"),
    # Virtual-time fleet simulator (DESIGN.md §16): wall time to simulate
    # the fixed seeded trace.  Absent from pre-DES baselines — tolerated.
    "des": ("wall_ms",),
    # Int8 attention stage vs the fused f32 path (DESIGN.md §17).
    # Absent from pre-PR-10 baselines — tolerated.
    "int8_attn": ("fused_f32_ms", "int8_attn_ms"),
    # Blocked (packed block-major B) vs flat projection GEMM drivers.
    "gemm_blocked": ("flat_ms", "blocked_ms"),
}
# gemm_blocked series carry m/k/n instead of a topology; absent fields
# resolve to None, so the extra keys don't disturb the other sections.
KEY_FIELDS = ("seq_len", "d_model", "heads", "lanes", "m", "k", "n")


def series_key(entry):
    return tuple(entry.get(k) for k in KEY_FIELDS)


def key_label(key):
    return "/".join(f"{name}={v}" for name, v in zip(KEY_FIELDS, key) if v is not None)


def load(path, required):
    try:
        with open(path) as f:
            return json.load(f)
    except FileNotFoundError:
        if required:
            print(f"error: {path} not found", file=sys.stderr)
            sys.exit(2)
        return None
    except json.JSONDecodeError as e:
        if required:
            print(f"error: {path} is not valid JSON: {e}", file=sys.stderr)
            sys.exit(2)
        print(f"notice: previous baseline {path} unreadable ({e}); skipping gate")
        return None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("previous", help="baseline BENCH_exec.json (prior CI artifact)")
    ap.add_argument("current", help="freshly measured BENCH_exec.json")
    ap.add_argument(
        "--max-regress",
        type=float,
        default=0.20,
        help="fail when new/old - 1 exceeds this on any series (default 0.20)",
    )
    args = ap.parse_args()

    prev = load(args.previous, required=False)
    if prev is None:
        print(f"notice: no previous baseline at {args.previous}; gate passes vacuously")
        return 0
    cur = load(args.current, required=True)

    failures = []
    missing = []
    compared = 0
    for section, fields in WALL_FIELDS.items():
        if section not in prev:
            if section in cur:
                print(
                    f"notice: baseline has no '{section}' section "
                    f"(older artifact); nothing to gate yet"
                )
            continue
        if section not in cur:
            missing.append(
                f"section '{section}' is in the baseline but missing from the new run"
            )
            continue
        prev_by_key = {series_key(e): e for e in prev.get(section, [])}
        for entry in cur.get(section, []):
            key = series_key(entry)
            base = prev_by_key.pop(key, None)
            if base is None:
                print(f"notice: {section} [{key_label(key)}] is new; no baseline")
                continue
            for field in fields:
                if field not in entry or field not in base:
                    continue
                old, new = float(base[field]), float(entry[field])
                if old <= 0.0:
                    continue
                compared += 1
                delta = new / old - 1.0
                line = (
                    f"{section} [{key_label(key)}] {field}: "
                    f"{old:.3f} -> {new:.3f} ms ({delta:+.1%})"
                )
                if delta > args.max_regress:
                    failures.append(line)
                    print(f"REGRESSION {line}")
                else:
                    print(f"ok         {line}")
        for key in prev_by_key:
            missing.append(
                f"{section} [{key_label(key)}] is in the baseline but missing "
                f"from the new run"
            )

    if missing:
        print(
            f"\n{len(missing)} baseline series missing from the new run "
            f"(dropped coverage is a failure, not a skip):",
            file=sys.stderr,
        )
        for line in missing:
            print(f"  {line}", file=sys.stderr)
        return 1
    if not compared:
        print("notice: no overlapping series between baseline and current; gate passes")
        return 0
    if failures:
        print(
            f"\n{len(failures)} series regressed beyond "
            f"{args.max_regress:.0%} wall time:",
            file=sys.stderr,
        )
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"\nall {compared} wall-time series within {args.max_regress:.0%} of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
